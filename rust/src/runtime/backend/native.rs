//! The native pure-Rust FastVPINNs training backend.
//!
//! Implements the paper's tensor-driven train step with no XLA, no
//! artifacts and no Python — and, since PR 2, in the paper's *tensor*
//! formulation rather than per-point loops:
//!
//! 1. all quadrature points of an element block are batched into
//!    `(points x width)` matrices and the tanh-MLP forward (carrying the
//!    two spatial input tangents) runs as cache-blocked GEMMs through
//!    [`crate::linalg::gemm`], with a fused bias + tanh +
//!    tangent-scaling epilogue per layer;
//! 2. the variational residual of the *generalized* weak form
//!    `r[e,j] = sum_q eps_q (G_x[e,j,q] du/dx + G_y[e,j,q] du/dy)
//!              + sum_q V[e,j,q] (b_q . grad u + c_q u) - F[e,j]`
//!    and its adjoint are blocked matrix products against the
//!    precomputed `G_x`/`G_y`/`V` premultiplier slabs. The coefficient
//!    fields `eps_q`/`b_q`/`c_q` come from the
//!    [`VariationalForm`](super::VariationalForm) hoisted once at
//!    construction: spatial constants fold into GEMV alphas (the
//!    closed-form fast path — bit-identical to the pre-form code),
//!    tables scale the tangents / V-contracted values per quadrature
//!    point. On the two-head inverse-space loss
//!    (`NativeLoss::InverseSpace`) `eps_q` is the softplus'd second
//!    network head instead, folded into the same blocked products by
//!    the identical tangent-scaling trick;
//! 3. the reverse pass (reverse-over-forward through the
//!    tangent-carrying MLP) is three accumulating GEMMs per layer for
//!    the weight gradients plus three GEMMs against `W^T` for the
//!    pulled-back adjoints, sharing the point-major tape layout the
//!    forward pass wrote;
//! 4. an Adam update (beta1 0.9, beta2 0.999, eps 1e-8).
//!
//! The element loop runs on the coordinator plane: a persistent
//! [`WorkerPool`] (spawned once per backend, parked between steps)
//! drives each step as one tick of the `AssignShards → Step → Reduce →
//! Sync` state machine in [`crate::coordinator::shard`]. Elements are
//! partitioned into a step-invariant, cost-aware [`ShardPlan`] (block-
//! aligned, weighted by quadrature-point count); workers claim shards
//! off a cursor but accumulate into *per-shard* partials, which a
//! fixed-order pairwise tree reduce then folds together. Because the
//! shard plan and the reduction tree depend only on the domain — never
//! on the worker count — per-step losses are bit-identical for any
//! `--workers` value. Every worker owns a preallocated [`Workspace`]
//! reused across steps, so the hot path performs no allocation.

use anyhow::{anyhow, bail, ensure, Result};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Mutex, PoisonError};

use super::form::VariationalForm;
use super::{Backend, BackendOpts, DataSource, StepStats};
use crate::coordinator::pool::WorkerPool;
use crate::coordinator::shard::{self, Phase, ShardPlan, Tick};
use crate::linalg::gemm::{gemm, gemv, GemmBufs};
use crate::linalg::simd;
use crate::runtime::checkpoint::{
    hash_f64_bits, Checkpoint, DomainFingerprint, TrainHyper,
};
use crate::util::rng::Rng;

/// Lock a per-worker/per-shard cell, riding mutex poisoning: a worker
/// panic already surfaced as an error from the pool tick, and every
/// accumulator is reset at the next `AssignShards` before reuse.
fn ride<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// `ride` for exclusively-owned cells (no locking, same poison ride).
fn ride_mut<T>(m: &mut Mutex<T>) -> &mut T {
    m.get_mut().unwrap_or_else(PoisonError::into_inner)
}

/// Target number of quadrature points batched per forward/backward
/// block. Rounded to whole elements; sized so a block's activations and
/// tapes stay cache-resident while the GEMMs are large enough to hit
/// the blocked kernel's throughput regime.
const TARGET_BLOCK_PTS: usize = 256;

/// Which objective *mode* the native step optimizes. The PDE itself —
/// the coefficient fields of the weak form — lives on the
/// [`crate::problems::Problem`] and is hoisted into a
/// [`VariationalForm`] at construction; the mode only decides what (if
/// anything) is trainable besides the network's u head.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum NativeLoss {
    /// Fixed coefficients from the problem's form: Poisson,
    /// convection-diffusion, Helmholtz (`c = -k²`), variable fields.
    Forward,
    /// The form's diffusion is replaced by a trainable scalar eps,
    /// plus sensor supervision (paper SS4.7.1).
    InverseConst,
    /// The form's diffusion is replaced by a trainable *field* from
    /// the network's second head, plus sensor supervision of u (paper
    /// SS4.7.2, Figs. 15-16); convection/reaction still come from the
    /// form.
    InverseSpace,
}

impl NativeLoss {
    /// Stable id of the mode (`"forward"`, `"inverse_const"`,
    /// `"inverse_space"`) — what checkpoints persist.
    pub fn mode_str(self) -> &'static str {
        match self {
            NativeLoss::Forward => "forward",
            NativeLoss::InverseConst => "inverse_const",
            NativeLoss::InverseSpace => "inverse_space",
        }
    }

    /// Parse a [`NativeLoss::mode_str`] id back (checkpoint loading).
    pub fn from_mode_str(s: &str) -> Result<NativeLoss> {
        match s {
            "forward" => Ok(NativeLoss::Forward),
            "inverse_const" => Ok(NativeLoss::InverseConst),
            "inverse_space" => Ok(NativeLoss::InverseSpace),
            other => bail!(
                "unknown loss mode '{other}' (known: forward, \
                 inverse_const, inverse_space)"
            ),
        }
    }
}

/// Numerically stable `ln(1 + e^z)` — the positivity map of the eps
/// head (a positive diffusion field keeps the inverse problem
/// well-posed for any parameter value).
pub(crate) fn softplus(z: f64) -> f64 {
    if z > 30.0 {
        z
    } else {
        z.exp().ln_1p()
    }
}

/// Stable logistic `1 / (1 + e^-z)` = d softplus / dz.
fn sigmoid(z: f64) -> f64 {
    if z >= 0.0 {
        1.0 / (1.0 + (-z).exp())
    } else {
        let e = z.exp();
        e / (1.0 + e)
    }
}

/// Static configuration of a native training run.
#[derive(Debug, Clone)]
pub struct NativeConfig {
    /// MLP widths, input to output (first must be 2, last 1). The
    /// paper's standard network is `[2, 30, 30, 30, 1]`.
    pub layers: Vec<usize>,
    /// Objective mode (the PDE itself comes from the problem).
    pub loss: NativeLoss,
    /// Dirichlet boundary sample count.
    pub nb: usize,
    /// Sensor count (inverse losses only).
    pub ns: usize,
}

impl NativeConfig {
    /// The paper's standard 30x3 forward setup (the PDE coefficients
    /// come from the problem's variational form).
    pub fn forward_std() -> NativeConfig {
        NativeConfig {
            layers: vec![2, 30, 30, 30, 1],
            loss: NativeLoss::Forward,
            nb: 400,
            ns: 0,
        }
    }

    /// The paper's SS4.7.2 two-head inverse-space setup: the standard
    /// 30x3 trunk shared by the u and eps heads, `ns` interior sensors
    /// (convection/reaction come from the problem's form).
    pub fn inverse_space_std(ns: usize) -> NativeConfig {
        NativeConfig {
            layers: vec![2, 30, 30, 30, 1],
            loss: NativeLoss::InverseSpace,
            nb: 400,
            ns,
        }
    }
}

// ---------------------------------------------------------------------
// MLP parameters
// ---------------------------------------------------------------------

/// A tanh MLP as a flat f64 parameter vector (per layer: row-major
/// `W[n_in, n_out]` then `b[n_out]`), usable standalone for
/// prediction-only workloads (e.g. the Table 1 timing run).
///
/// Two-head networks ([`Mlp::glorot_two_head`]) share the trunk and the
/// u output layer with the single-head layout, and append one extra
/// linear head `(last hidden width -> 1)` whose softplus'd output is
/// the trainable diffusion field `eps(x, y)` of the inverse-space loss.
#[derive(Debug, Clone)]
pub struct Mlp {
    /// Layer widths, input to output.
    pub layers: Vec<usize>,
    /// Flat parameters (per layer: row-major W then b; eps head last).
    pub theta: Vec<f64>,
    /// (w_offset, b_offset) per weight layer.
    offsets: Vec<(usize, usize)>,
    /// (w_offset, b_offset) of the eps head, when two-head.
    eps_head: Option<(usize, usize)>,
}

impl Mlp {
    /// Glorot-uniform weights, zero biases (same distribution and RNG as
    /// the XLA path's init).
    pub fn glorot(layers: &[usize], seed: u64) -> Result<Mlp> {
        Mlp::glorot_with(layers, seed, false)
    }

    /// [`Mlp::glorot`] plus the eps head for the two-head inverse-space
    /// network; the head's weights are drawn from the same RNG stream
    /// after the trunk's, so single- and two-head nets with equal seeds
    /// share their trunk init.
    pub fn glorot_two_head(layers: &[usize], seed: u64) -> Result<Mlp> {
        Mlp::glorot_with(layers, seed, true)
    }

    fn glorot_with(layers: &[usize], seed: u64, two_head: bool)
        -> Result<Mlp> {
        ensure!(layers.len() >= 2, "need at least input+output layer");
        ensure!(layers[0] == 2, "input width must be 2 (x, y)");
        ensure!(*layers.last().unwrap() == 1, "output width must be 1");
        let mut rng = Rng::new(seed);
        let mut theta = Vec::new();
        let mut offsets = Vec::new();
        for w in layers.windows(2) {
            let (nin, nout) = (w[0], w[1]);
            let w_off = theta.len();
            theta.extend(rng.glorot(nin, nout).iter().map(|&v| v as f64));
            let b_off = theta.len();
            theta.resize(b_off + nout, 0.0);
            offsets.push((w_off, b_off));
        }
        let eps_head = if two_head {
            let nin = layers[layers.len() - 2];
            let w_off = theta.len();
            theta.extend(rng.glorot(nin, 1).iter().map(|&v| v as f64));
            let b_off = theta.len();
            theta.push(0.0);
            Some((w_off, b_off))
        } else {
            None
        };
        Ok(Mlp { layers: layers.to_vec(), theta, offsets, eps_head })
    }

    /// Rebuild a network from a persisted flat parameter vector (the
    /// checkpoint path): same layout as [`Mlp::glorot`] /
    /// [`Mlp::glorot_two_head`], but with `theta` supplied instead of
    /// drawn — so a reloaded network reproduces the exporting one's
    /// predictions bit-for-bit. Validates the parameter count against
    /// the layer widths.
    pub fn from_theta(layers: &[usize], two_head: bool, theta: Vec<f64>)
        -> Result<Mlp> {
        ensure!(layers.len() >= 2, "need at least input+output layer");
        ensure!(layers[0] == 2, "input width must be 2 (x, y)");
        ensure!(*layers.last().unwrap() == 1, "output width must be 1");
        let mut offsets = Vec::new();
        let mut n = 0usize;
        for w in layers.windows(2) {
            let (nin, nout) = (w[0], w[1]);
            offsets.push((n, n + nin * nout));
            n += nin * nout + nout;
        }
        let eps_head = if two_head {
            let nin = layers[layers.len() - 2];
            let head = (n, n + nin);
            n += nin + 1;
            Some(head)
        } else {
            None
        };
        ensure!(
            theta.len() == n,
            "theta has {} values but layers {:?}{} need {n}",
            theta.len(),
            layers,
            if two_head { " + eps head" } else { "" }
        );
        Ok(Mlp { layers: layers.to_vec(), theta, offsets, eps_head })
    }

    /// Whether this network carries the eps field head.
    pub fn two_head(&self) -> bool {
        self.eps_head.is_some()
    }

    /// Weight matrix (row-major `nin x nout`) and bias of weight stage
    /// `l` — read-only views for serve-side repacking (the f32
    /// inference path packs these once per session).
    pub fn stage_params(&self, l: usize) -> (&[f64], &[f64]) {
        let (nin, nout) = (self.layers[l], self.layers[l + 1]);
        let (w_off, b_off) = self.offsets[l];
        (
            &self.theta[w_off..w_off + nin * nout],
            &self.theta[b_off..b_off + nout],
        )
    }

    /// Eps-head weights (`last hidden width` of them) and bias, when
    /// two-head.
    pub fn eps_params(&self) -> Option<(&[f64], f64)> {
        self.eps_head.map(|(w_off, b_off)| {
            let nin = self.layers[self.layers.len() - 2];
            (&self.theta[w_off..w_off + nin], self.theta[b_off])
        })
    }

    /// Flat parameter count (both heads).
    pub fn n_params(&self) -> usize {
        self.theta.len()
    }

    /// Number of weight layers.
    fn n_stages(&self) -> usize {
        self.layers.len() - 1
    }

    fn max_width(&self) -> usize {
        self.layers.iter().copied().max().unwrap_or(1)
    }

    /// Value-only forward at a batch of points (prediction path), routed
    /// through the same blocked GEMM kernel as training. Allocates a
    /// fresh [`EvalScratch`]; timed repeated passes (Table 1) should
    /// hold one and call [`Mlp::eval_with`].
    pub fn eval(&self, points: &[[f64; 2]]) -> Vec<f32> {
        let mut scratch = EvalScratch::new(self);
        self.eval_with(points, &mut scratch)
    }

    /// [`Mlp::eval`] with caller-owned scratch, so repeated prediction
    /// passes pay no per-call allocation.
    pub fn eval_with(
        &self,
        points: &[[f64; 2]],
        scratch: &mut EvalScratch,
    ) -> Vec<f32> {
        self.eval_heads_with(points, scratch).0
    }

    /// Evaluate every output head: `(u, Some(eps))` for two-head
    /// networks, `(u, None)` otherwise.
    pub fn eval_heads(&self, points: &[[f64; 2]])
        -> (Vec<f32>, Option<Vec<f32>>) {
        let mut scratch = EvalScratch::new(self);
        self.eval_heads_with(points, &mut scratch)
    }

    /// [`Mlp::eval_heads`] with caller-owned scratch. The trunk runs
    /// once per block; both heads read the same last hidden activation.
    pub fn eval_heads_with(
        &self,
        points: &[[f64; 2]],
        scratch: &mut EvalScratch,
    ) -> (Vec<f32>, Option<Vec<f32>>) {
        let wmax = self.max_width();
        assert!(scratch.cur.len() >= EVAL_BLOCK * wmax,
                "EvalScratch built for a narrower network");
        let last = self.n_stages() - 1;
        let mut out = Vec::with_capacity(points.len());
        let mut out_eps = self
            .eps_head
            .map(|_| Vec::with_capacity(points.len()));
        for chunk in points.chunks(EVAL_BLOCK) {
            let n = chunk.len();
            for (p, pt) in chunk.iter().enumerate() {
                scratch.xy[2 * p] = pt[0];
                scratch.xy[2 * p + 1] = pt[1];
            }
            // trunk: hidden layers into `cur` (kept for both heads)
            for l in 0..last {
                let (nin, nout) = (self.layers[l], self.layers[l + 1]);
                let (w_off, b_off) = self.offsets[l];
                let w = &self.theta[w_off..w_off + nin * nout];
                let bias = &self.theta[b_off..b_off + nout];
                let a_in: &[f64] = if l == 0 {
                    &scratch.xy[..2 * n]
                } else {
                    &scratch.cur[..n * nin]
                };
                gemm(&mut scratch.bufs, n, nout, nin, 1.0, a_in, false,
                     w, false, 0.0, &mut scratch.z);
                for p in 0..n {
                    for (j, &bj) in bias.iter().enumerate() {
                        scratch.cur[p * nout + j] =
                            scratch.z[p * nout + j] + bj;
                    }
                }
                simd::tanh_block(&mut scratch.cur[..n * nout]);
            }
            let nin = self.layers[last];
            let a_in: &[f64] = if last == 0 {
                &scratch.xy[..2 * n]
            } else {
                &scratch.cur[..n * nin]
            };
            // u head
            let (w_off, b_off) = self.offsets[last];
            let w = &self.theta[w_off..w_off + nin];
            gemm(&mut scratch.bufs, n, 1, nin, 1.0, a_in, false, w,
                 false, 0.0, &mut scratch.z);
            let bu = self.theta[b_off];
            out.extend((0..n).map(|p| (scratch.z[p] + bu) as f32));
            // eps head (softplus positivity)
            if let (Some((we_off, be_off)), Some(oe)) =
                (self.eps_head, out_eps.as_mut())
            {
                let we = &self.theta[we_off..we_off + nin];
                gemm(&mut scratch.bufs, n, 1, nin, 1.0, a_in, false, we,
                     false, 0.0, &mut scratch.z);
                let be = self.theta[be_off];
                oe.extend(
                    (0..n).map(|p| softplus(scratch.z[p] + be) as f32));
            }
        }
        (out, out_eps)
    }

    /// Scalar reference forward with spatial tangents — the
    /// pre-tensorization per-point recurrence, kept as the single
    /// ground-truth implementation the batched kernels are tested
    /// against. Returns `(u, du/dx, du/dy)`.
    pub fn forward_point_reference(&self, x: f64, y: f64)
        -> (f64, f64, f64) {
        let wmax = self.max_width();
        let mut cur = [vec![0.0; wmax], vec![0.0; wmax], vec![0.0; wmax]];
        let mut nxt = [vec![0.0; wmax], vec![0.0; wmax], vec![0.0; wmax]];
        cur[0][0] = x;
        cur[0][1] = y;
        cur[1][0] = 1.0;
        cur[2][1] = 1.0;
        let last = self.n_stages() - 1;
        for (l, win) in self.layers.windows(2).enumerate() {
            let (nin, nout) = (win[0], win[1]);
            let (w_off, b_off) = self.offsets[l];
            let w = &self.theta[w_off..w_off + nin * nout];
            let b = &self.theta[b_off..b_off + nout];
            for j in 0..nout {
                let mut z = b[j];
                let mut zx = 0.0;
                let mut zy = 0.0;
                for i in 0..nin {
                    let wij = w[i * nout + j];
                    z += cur[0][i] * wij;
                    zx += cur[1][i] * wij;
                    zy += cur[2][i] * wij;
                }
                if l < last {
                    let a = z.tanh();
                    let s = 1.0 - a * a;
                    nxt[0][j] = a;
                    nxt[1][j] = s * zx;
                    nxt[2][j] = s * zy;
                } else {
                    nxt[0][j] = z;
                    nxt[1][j] = zx;
                    nxt[2][j] = zy;
                }
            }
            std::mem::swap(&mut cur, &mut nxt);
        }
        (cur[0][0], cur[1][0], cur[2][0])
    }

    /// Tensorized forward over a block of `n` points (`pts` is
    /// interleaved x,y), carrying the spatial tangents. Per layer this
    /// is three `(n x nin) @ (nin x nout)` blocked GEMMs (value, x- and
    /// y-tangent) plus the fused bias + tanh + tangent-scaling
    /// epilogue; tapes land point-major in `ws` for the backward pass.
    /// `with_eps` gates the eps head: the variational pass needs the
    /// field at quadrature points, the boundary/sensor penalty passes
    /// (which supervise u only) skip it.
    fn forward_block(
        &self,
        ws: &mut Workspace,
        pts: &[f64],
        n: usize,
        with_eps: bool,
    ) {
        debug_assert!(pts.len() >= 2 * n && n <= ws.block_pts);
        let last = self.n_stages() - 1;
        for l in 0..=last {
            let (nin, nout) = (self.layers[l], self.layers[l + 1]);
            let (w_off, b_off) = self.offsets[l];
            let w = &self.theta[w_off..w_off + nin * nout];
            let bias = &self.theta[b_off..b_off + nout];
            let (prev, rest) = ws.tapes.split_at_mut(l);
            // value pre-activation into scratch
            let a_in: &[f64] =
                if l == 0 { &pts[..2 * n] } else { &prev[l - 1].a };
            gemm(&mut ws.bufs, n, nout, nin, 1.0, a_in, false, w, false,
                 0.0, &mut ws.z);
            if l < last {
                let t = &mut rest[0];
                // tangent pre-activations straight into the tape
                if l == 0 {
                    // input tangents are the constant basis e_x, e_y:
                    // zx[p,j] = W[0,j], zy[p,j] = W[1,j]
                    for p in 0..n {
                        t.zx[p * nout..(p + 1) * nout]
                            .copy_from_slice(&w[..nout]);
                        t.zy[p * nout..(p + 1) * nout]
                            .copy_from_slice(&w[nout..2 * nout]);
                    }
                } else {
                    let tin = &prev[l - 1];
                    gemm(&mut ws.bufs, n, nout, nin, 1.0, &tin.ax, false,
                         w, false, 0.0, &mut t.zx);
                    gemm(&mut ws.bufs, n, nout, nin, 1.0, &tin.ay, false,
                         w, false, 0.0, &mut t.zy);
                }
                // epilogue: bias add, then the block tanh (vectorized
                // on AVX2, libm otherwise), then tangent scaling
                // s = 1 - a^2. The fission keeps each value's FP
                // sequence identical to the old fused loop.
                for p in 0..n {
                    let o = p * nout;
                    for (j, &bj) in bias.iter().enumerate() {
                        t.a[o + j] = ws.z[o + j] + bj;
                    }
                }
                simd::tanh_block(&mut t.a[..n * nout]);
                for o in 0..n * nout {
                    let a = t.a[o];
                    let s = 1.0 - a * a;
                    t.ax[o] = s * t.zx[o];
                    t.ay[o] = s * t.zy[o];
                }
            } else {
                // output layer (width 1): bias only, tangents raw
                debug_assert_eq!(nout, 1);
                if l == 0 {
                    for p in 0..n {
                        ws.ux[p] = w[0];
                        ws.uy[p] = w[1];
                    }
                } else {
                    let tin = &prev[l - 1];
                    gemm(&mut ws.bufs, n, 1, nin, 1.0, &tin.ax, false, w,
                         false, 0.0, &mut ws.ux);
                    gemm(&mut ws.bufs, n, 1, nin, 1.0, &tin.ay, false, w,
                         false, 0.0, &mut ws.uy);
                }
                for p in 0..n {
                    ws.u[p] = ws.z[p] + bias[0];
                }
            }
        }
        if !with_eps {
            return;
        }
        // eps head (two-head nets): value-only linear layer off the
        // same last hidden activation, then the softplus positivity
        // map. Tapes `eps_z` (pre-activation) and `epsv` (the field)
        // feed the residual contraction and the backward pass.
        if let Some((we_off, be_off)) = self.eps_head {
            let nin = self.layers[last];
            let we = &self.theta[we_off..we_off + nin];
            let be = self.theta[be_off];
            if last == 0 {
                for p in 0..n {
                    ws.eps_z[p] =
                        pts[2 * p] * we[0] + pts[2 * p + 1] * we[1] + be;
                }
            } else {
                let t = &ws.tapes[last - 1];
                gemm(&mut ws.bufs, n, 1, nin, 1.0, &t.a, false, we,
                     false, 0.0, &mut ws.eps_z);
                for p in 0..n {
                    ws.eps_z[p] += be;
                }
            }
            for p in 0..n {
                ws.epsv[p] = softplus(ws.eps_z[p]);
            }
        }
    }

    /// Tensorized reverse pass over a block of `n` points. Seeds (the
    /// per-point adjoints of `u`, `du/dx`, `du/dy` — plus `eps` via
    /// `ws.seed_e` on two-head nets) are read from
    /// `ws.seed_u/seed_x/seed_y/seed_e`; parameter gradients accumulate
    /// into `grad` (flat `theta` layout). Per layer: three accumulating
    /// `A^T @ G` GEMMs for the weight gradients, column sums for the
    /// bias, three `G @ W^T` GEMMs for the pulled-back adjoints, and
    /// the tanh adjoint against the forward tape. The eps head's
    /// adjoint (softplus then its linear layer) is folded into the
    /// trunk's value adjoint at the last hidden layer; `with_eps`
    /// false (penalty passes — no eps adjoint exists) skips the head
    /// entirely.
    fn backward_block(
        &self,
        ws: &mut Workspace,
        grad: &mut [f64],
        pts: &[f64],
        n: usize,
        with_eps: bool,
    ) {
        debug_assert!(pts.len() >= 2 * n && n <= ws.block_pts);
        let last = self.n_stages() - 1;
        let eps_head = if with_eps { self.eps_head } else { None };
        // output layer has width 1: adjoint matrices start as columns
        ws.ga[..n].copy_from_slice(&ws.seed_u[..n]);
        ws.gax[..n].copy_from_slice(&ws.seed_x[..n]);
        ws.gay[..n].copy_from_slice(&ws.seed_y[..n]);
        // eps head: softplus adjoint (`gez = seed_e * sigmoid(z)`) then
        // the head's linear layer. Its pulled-back value adjoint joins
        // the u head's before the trunk walk below (at l == last).
        if let Some((we_off, be_off)) = eps_head {
            let nin = self.layers[last];
            for p in 0..n {
                ws.gez[p] = ws.seed_e[p] * sigmoid(ws.eps_z[p]);
            }
            grad[be_off] += ws.gez[..n].iter().sum::<f64>();
            if last == 0 {
                for p in 0..n {
                    grad[we_off] += pts[2 * p] * ws.gez[p];
                    grad[we_off + 1] += pts[2 * p + 1] * ws.gez[p];
                }
            } else {
                let t = &ws.tapes[last - 1];
                gemm(&mut ws.bufs, nin, 1, n, 1.0, &t.a, true, &ws.gez,
                     false, 1.0, &mut grad[we_off..we_off + nin]);
            }
        }
        for l in (0..=last).rev() {
            let (nin, nout) = (self.layers[l], self.layers[l + 1]);
            let (w_off, b_off) = self.offsets[l];
            // bias gradient: column sums of the value adjoint
            for p in 0..n {
                let row = &ws.ga[p * nout..(p + 1) * nout];
                for (g, &v) in
                    grad[b_off..b_off + nout].iter_mut().zip(row)
                {
                    *g += v;
                }
            }
            // weight gradient: A_in^T Gz + Ax_in^T Gzx + Ay_in^T Gzy
            let gw = &mut grad[w_off..w_off + nin * nout];
            if l == 0 {
                // input activations are (x, y); the input tangents are
                // the constant e_x/e_y basis, so their contribution to
                // row i of the weight gradient is a plain column sum.
                for p in 0..n {
                    let (x, y) = (pts[2 * p], pts[2 * p + 1]);
                    let o = p * nout;
                    for j in 0..nout {
                        gw[j] += x * ws.ga[o + j] + ws.gax[o + j];
                        gw[nout + j] += y * ws.ga[o + j] + ws.gay[o + j];
                    }
                }
            } else {
                let tin = &ws.tapes[l - 1];
                gemm(&mut ws.bufs, nin, nout, n, 1.0, &tin.a, true,
                     &ws.ga, false, 1.0, gw);
                gemm(&mut ws.bufs, nin, nout, n, 1.0, &tin.ax, true,
                     &ws.gax, false, 1.0, gw);
                gemm(&mut ws.bufs, nin, nout, n, 1.0, &tin.ay, true,
                     &ws.gay, false, 1.0, gw);
            }
            if l == 0 {
                break;
            }
            // pull adjoints back through W, then through the tanh of
            // the previous hidden layer (using its tape)
            let w = &self.theta[w_off..w_off + nin * nout];
            gemm(&mut ws.bufs, n, nin, nout, 1.0, &ws.ga, false, w, true,
                 0.0, &mut ws.gb);
            if l == last {
                if let Some((we_off, _)) = eps_head {
                    // merge the eps head's value adjoint into the
                    // trunk's: gb += gez @ We^T
                    let we = &self.theta[we_off..we_off + nin];
                    gemm(&mut ws.bufs, n, nin, 1, 1.0, &ws.gez, false,
                         we, true, 1.0, &mut ws.gb);
                }
            }
            gemm(&mut ws.bufs, n, nin, nout, 1.0, &ws.gax, false, w,
                 true, 0.0, &mut ws.gbx);
            gemm(&mut ws.bufs, n, nin, nout, 1.0, &ws.gay, false, w,
                 true, 0.0, &mut ws.gby);
            let t = &ws.tapes[l - 1];
            for p in 0..n {
                let o = p * nin;
                for i in 0..nin {
                    let a = t.a[o + i];
                    let s = 1.0 - a * a;
                    let ds = -2.0 * a * s; // d s / d z
                    let gpx = ws.gbx[o + i];
                    let gpy = ws.gby[o + i];
                    ws.ga[o + i] = ws.gb[o + i] * s
                        + (gpx * t.zx[o + i] + gpy * t.zy[o + i]) * ds;
                    ws.gax[o + i] = gpx * s;
                    ws.gay[o + i] = gpy * s;
                }
            }
        }
    }
}

/// Points per [`Mlp::eval_with`] block.
const EVAL_BLOCK: usize = 512;

/// Reusable buffers for [`Mlp::eval_with`] — allocate once when timing
/// repeated prediction passes; [`Mlp::eval`] wraps a fresh one per
/// call. Sized for the network it was built from.
pub struct EvalScratch {
    bufs: GemmBufs,
    xy: Vec<f64>,
    cur: Vec<f64>,
    z: Vec<f64>,
}

impl EvalScratch {
    /// Buffers sized for `mlp`'s widest layer.
    pub fn new(mlp: &Mlp) -> EvalScratch {
        let wmax = mlp.max_width();
        EvalScratch {
            bufs: GemmBufs::new(),
            xy: vec![0.0; 2 * EVAL_BLOCK],
            cur: vec![0.0; EVAL_BLOCK * wmax],
            z: vec![0.0; EVAL_BLOCK * wmax],
        }
    }
}

// ---------------------------------------------------------------------
// Per-thread forward/backward workspace
// ---------------------------------------------------------------------

/// Stored forward state of one hidden layer over a block of points,
/// point-major `[p * width + j]` — exactly the layout the GEMM kernels
/// produce, shared between the forward and backward passes.
struct LayerTape {
    a: Vec<f64>,  // tanh activations
    ax: Vec<f64>, // post-activation x tangents = s * zx
    ay: Vec<f64>,
    zx: Vec<f64>, // pre-activation x tangents
    zy: Vec<f64>,
}

/// Block-sized buffers for the batched forward/backward passes and the
/// residual contraction. Allocated once per thread and reused every
/// step — the hot path never allocates.
struct Workspace {
    block_pts: usize,
    tapes: Vec<LayerTape>, // one per hidden layer
    z: Vec<f64>,           // pre-activation scratch (block_pts x wmax)
    u: Vec<f64>,           // per-point outputs
    ux: Vec<f64>,
    uy: Vec<f64>,
    ga: Vec<f64>, // adjoint matrices (block_pts x wmax)
    gax: Vec<f64>,
    gay: Vec<f64>,
    gb: Vec<f64>, // pull-back scratch
    gbx: Vec<f64>,
    gby: Vec<f64>,
    seed_u: Vec<f64>, // per-point backward seeds
    seed_x: Vec<f64>,
    seed_y: Vec<f64>,
    seed_e: Vec<f64>, // per-point eps field adjoint (two-head nets)
    cvals: Vec<f64>, // per-(element, j) pre-eps contraction
    resid: Vec<f64>, // per-(element, j) residual
    dq: Vec<f64>,    // per-point V-weighted values b . grad u + c u
    tv: Vec<f64>,    // per-point V^T r pull-back (conv/reaction seeds)
    eps_z: Vec<f64>, // eps head pre-activation tape
    epsv: Vec<f64>,  // eps head field values softplus(eps_z)
    gez: Vec<f64>,   // eps head pre-activation adjoint
    uxs: Vec<f64>,   // eps-scaled tangents eps(x_q) * du/dx
    uys: Vec<f64>,
    bufs: GemmBufs,
}

impl Workspace {
    fn new(mlp: &Mlp, block_pts: usize, jrows: usize) -> Workspace {
        let wmax = mlp.max_width();
        let bp = block_pts.max(1);
        let tapes = mlp.layers[1..mlp.layers.len() - 1]
            .iter()
            .map(|&w| LayerTape {
                a: vec![0.0; w * bp],
                ax: vec![0.0; w * bp],
                ay: vec![0.0; w * bp],
                zx: vec![0.0; w * bp],
                zy: vec![0.0; w * bp],
            })
            .collect();
        Workspace {
            block_pts: bp,
            tapes,
            z: vec![0.0; wmax * bp],
            u: vec![0.0; bp],
            ux: vec![0.0; bp],
            uy: vec![0.0; bp],
            ga: vec![0.0; wmax * bp],
            gax: vec![0.0; wmax * bp],
            gay: vec![0.0; wmax * bp],
            gb: vec![0.0; wmax * bp],
            gbx: vec![0.0; wmax * bp],
            gby: vec![0.0; wmax * bp],
            seed_u: vec![0.0; bp],
            seed_x: vec![0.0; bp],
            seed_y: vec![0.0; bp],
            seed_e: vec![0.0; bp],
            cvals: vec![0.0; jrows.max(1)],
            resid: vec![0.0; jrows.max(1)],
            dq: vec![0.0; bp],
            tv: vec![0.0; bp],
            eps_z: vec![0.0; bp],
            epsv: vec![0.0; bp],
            gez: vec![0.0; bp],
            uxs: vec![0.0; bp],
            uys: vec![0.0; bp],
            bufs: GemmBufs::new(),
        }
    }
}

/// Per-shard gradient + loss accumulator, reused across steps. Keyed
/// by shard (not by worker), so which worker computes a shard never
/// influences any bit of the reduction.
struct Partial {
    grad: Vec<f64>,
    var_sq: f64,
    geps: f64,
}

impl Partial {
    fn new(n_net: usize) -> Partial {
        Partial { grad: vec![0.0; n_net], var_sq: 0.0, geps: 0.0 }
    }

    fn reset(&mut self) {
        self.grad.fill(0.0);
        self.var_sq = 0.0;
        self.geps = 0.0;
    }

    /// Fold `other` into `self` — one edge of the reduction tree.
    fn merge(&mut self, other: &Partial) {
        for (g, og) in self.grad.iter_mut().zip(&other.grad) {
            *g += og;
        }
        self.var_sq += other.var_sq;
        self.geps += other.geps;
    }
}

/// Chunked penalty pass shared by the Dirichlet and sensor terms:
/// forward/backward the blocked MLP over `(pts_flat, targets)`,
/// seeding `du = 2*weight/n * (u - target)` per point; accumulates
/// parameter gradients into `grad` and returns the sum of squared
/// errors.
fn penalty_pass(
    net: &Mlp,
    ws: &mut Workspace,
    grad: &mut [f64],
    pts_flat: &[f64],
    targets: &[f64],
    weight: f64,
) -> f64 {
    let n_total = targets.len();
    let bp = ws.block_pts;
    let mut sq = 0.0;
    let mut off = 0;
    while off < n_total {
        let n = bp.min(n_total - off);
        let pts = &pts_flat[2 * off..2 * (off + n)];
        // penalties supervise u only: with_eps = false skips the eps
        // head's forward and (zero-adjoint) backward entirely
        net.forward_block(ws, pts, n, false);
        ws.seed_x[..n].fill(0.0);
        ws.seed_y[..n].fill(0.0);
        for k in 0..n {
            let d = ws.u[k] - targets[off + k];
            sq += d * d;
            ws.seed_u[k] = 2.0 * weight / n_total as f64 * d;
        }
        net.backward_block(ws, grad, pts, n, false);
        off += n;
    }
    sq
}

// ---------------------------------------------------------------------
// The backend
// ---------------------------------------------------------------------

/// The pure-Rust training backend (see the module docs for the step
/// algorithm). Holds the network, optimizer state and step-invariant
/// data tensors; built from a [`DataSource`] via [`NativeBackend::new`]
/// or restored from a persisted artifact via
/// [`NativeBackend::from_checkpoint`].
pub struct NativeBackend {
    cfg: NativeConfig,
    net: Mlp,
    /// The hoisted weak form: eps/b/c as scalars or per-quadrature-
    /// point tables (step-invariant, never re-evaluated).
    form: VariationalForm,
    /// Loss family id derived from mode + form at construction.
    kind: &'static str,
    /// Problem instance label (`Problem::name`), exported into
    /// checkpoints.
    problem_label: String,
    /// Identity of the assembled domain (checkpoint export + resume
    /// verification).
    fingerprint: DomainFingerprint,
    /// RNG seed (weights + boundary/sensor sampling), persisted so a
    /// resumed run re-draws identical point sets.
    seed: u64,
    /// Initial trainable-eps guess, persisted for resume.
    eps_init: f64,
    /// Trainable scalar diffusion (`loss == InverseConst` only).
    eps: f64,
    // Adam state over net params (+ eps slot when trainable)
    m: Vec<f64>,
    v: Vec<f64>,
    // Step-invariant data, owned (f64 — no f32 runtime boundary here).
    // Owning copies of gx/gy/v/quad_xy doubles peak memory during
    // construction, but lets the caller drop the AssembledDomain
    // afterwards — at paper scale keep only one of the two alive.
    ne: usize,
    nt: usize,
    nq: usize,
    gx: Vec<f64>,
    gy: Vec<f64>,
    vmat: Vec<f64>,
    f_mat: Vec<f64>,
    quad_xy: Vec<f64>,
    /// Boundary samples, interleaved x,y (GEMM-ready).
    bd_flat: Vec<f64>,
    bd_u: Vec<f64>,
    sensor_flat: Vec<f64>,
    sensor_u: Vec<f64>,
    tau: f64,
    gamma: f64,
    n_threads: usize,
    /// Elements batched per forward/backward block.
    block_elems: usize,
    /// Reused flat gradient over the optimized parameters.
    grad: Vec<f64>,
    /// Persistent worker threads, parked between ticks.
    pool: WorkerPool,
    /// Per-worker workspaces, reused each step (Mutex only to share
    /// `&self` with the pool — uncontended, one lock per tick).
    worker_ws: Vec<Mutex<Workspace>>,
    /// Per-shard partial accumulators, reused each step.
    shard_partials: Vec<Mutex<Partial>>,
    /// Step-invariant cost-aware element partition.
    plan: ShardPlan,
    /// Phase-order guard for the coordinator loop.
    tick: Tick,
}

impl NativeBackend {
    /// Build a backend from assembled data: hoist the problem's
    /// coefficient fields into the [`VariationalForm`], draw the
    /// Glorot init and boundary/sensor samples from `opts.seed`, and
    /// allocate the per-thread workspaces.
    pub fn new(
        cfg: &NativeConfig,
        src: &DataSource<'_>,
        opts: &BackendOpts,
    ) -> Result<NativeBackend> {
        let dom = src.domain.ok_or_else(|| anyhow!(
            "the native backend needs assembled premultiplier tensors \
             (DataSource.domain is None)"
        ))?;
        ensure!(cfg.nb >= 4, "need at least 4 boundary samples");
        let trainable_eps = cfg.loss == NativeLoss::InverseConst;
        let two_head = cfg.loss == NativeLoss::InverseSpace;
        // hoist the problem's coefficient fields once: constants stay
        // scalars (GEMV-alpha fast path), varying fields become
        // per-quadrature-point tables
        let form = VariationalForm::from_problem(src.problem, dom);
        let kind: &'static str = match cfg.loss {
            NativeLoss::InverseConst => "inverse_const",
            NativeLoss::InverseSpace => "inverse_space",
            NativeLoss::Forward => {
                match (form.has_reaction(), form.has_convection()) {
                    (true, true) => "cd_reaction",
                    (true, false) => "helmholtz",
                    (false, true) => "cd",
                    (false, false) => "poisson",
                }
            }
        };
        // the scalar slot is only meaningful when trainable; on the
        // other modes eps comes from the form / the network head
        let eps = if trainable_eps { opts.eps_init } else { 0.0 };

        let net = if two_head {
            Mlp::glorot_two_head(&cfg.layers, opts.seed)?
        } else {
            Mlp::glorot(&cfg.layers, opts.seed)?
        };
        let n_opt = net.n_params() + usize::from(trainable_eps);

        let f_mat = dom.force_matrix(|x, y| src.problem.forcing(x, y));
        let bd_pts = src.mesh.sample_boundary(cfg.nb);
        let bd_u: Vec<f64> = bd_pts
            .iter()
            .map(|p| src.problem.boundary(p[0], p[1]))
            .collect();
        let bd_flat: Vec<f64> =
            bd_pts.iter().flat_map(|p| [p[0], p[1]]).collect();

        let (sensor_flat, sensor_u) = if trainable_eps || two_head {
            ensure!(cfg.ns > 0, "{kind} needs ns > 0 sensor points");
            let pts = src.mesh.sample_interior(cfg.ns, opts.seed + 1);
            let vals: Vec<f64> = pts
                .iter()
                .map(|p| match src.sensor_values {
                    Some(f) => Ok(f(p[0], p[1])),
                    None => src.problem.exact(p[0], p[1]).ok_or_else(|| {
                        anyhow!(
                            "problem '{}' has no exact solution; provide \
                             DataSource.sensor_values",
                            src.problem.name()
                        )
                    }),
                })
                .collect::<Result<_>>()?;
            let flat: Vec<f64> =
                pts.iter().flat_map(|p| [p[0], p[1]]).collect();
            (flat, vals)
        } else {
            (Vec::new(), Vec::new())
        };

        // Worker-count precedence: `--workers` (BackendOpts::workers)
        // wins, the FASTVPINNS_THREADS env var is a documented alias,
        // and the machine's available parallelism is the default —
        // always clamped to the element count. The shard plan and the
        // reduction tree are worker-count-independent, so this choice
        // affects wall-clock only: per-step losses are bit-identical
        // for any value. Zero or an unparsable env value errors rather
        // than silently falling back.
        let configured = match opts.workers {
            Some(n) => {
                ensure!(n > 0,
                        "--workers must be a positive integer, got 0");
                n
            }
            None => match std::env::var("FASTVPINNS_THREADS") {
                Ok(v) => v
                    .parse::<usize>()
                    .ok()
                    .filter(|&n| n > 0)
                    .ok_or_else(|| anyhow!(
                        "FASTVPINNS_THREADS must be a positive \
                         integer, got '{v}'"))?,
                Err(_) => std::thread::available_parallelism()
                    .map(|n| n.get())
                    .unwrap_or(1),
            },
        };
        let n_threads = configured.min(dom.ne.max(1));

        let (blo, bhi) = src.mesh.bbox();
        let fingerprint = DomainFingerprint {
            ne: dom.ne,
            nt: dom.nt,
            nq: dom.nq,
            n_points: src.mesh.n_points(),
            n_cells: src.mesh.n_cells(),
            bbox: [blo[0], blo[1], bhi[0], bhi[1]],
            quad_hash: hash_f64_bits(&dom.quad_xy),
        };

        let mut backend = NativeBackend {
            cfg: cfg.clone(),
            net,
            form,
            kind,
            problem_label: src.problem.name().to_string(),
            fingerprint,
            seed: opts.seed,
            eps_init: opts.eps_init,
            eps,
            m: vec![0.0; n_opt],
            v: vec![0.0; n_opt],
            ne: dom.ne,
            nt: dom.nt,
            nq: dom.nq,
            gx: dom.gx.clone(),
            gy: dom.gy.clone(),
            vmat: dom.v.clone(),
            f_mat,
            quad_xy: dom.quad_xy.clone(),
            bd_flat,
            bd_u,
            sensor_flat,
            sensor_u,
            tau: opts.tau,
            gamma: opts.gamma,
            n_threads,
            block_elems: (TARGET_BLOCK_PTS / dom.nq.max(1)).max(1),
            grad: vec![0.0; n_opt],
            pool: WorkerPool::new(n_threads)?,
            worker_ws: Vec::new(),
            shard_partials: Vec::new(),
            plan: ShardPlan::default(),
            tick: Tick::default(),
        };
        backend.rebuild_workspaces();
        Ok(backend)
    }

    /// (Re)allocate the per-worker workspaces, the shard plan and the
    /// per-shard accumulators for the current block size — called at
    /// construction (and from the block-size test hook); the step loop
    /// reuses them.
    fn rebuild_workspaces(&mut self) {
        let bp = self.block_elems * self.nq;
        let jrows = self.block_elems * self.nt;
        let n_net = self.net.n_params();
        self.worker_ws = (0..self.n_threads)
            .map(|_| Mutex::new(Workspace::new(&self.net, bp, jrows)))
            .collect();
        self.plan =
            ShardPlan::build(self.ne, self.nq, self.block_elems);
        self.shard_partials = (0..self.plan.n_shards())
            .map(|_| Mutex::new(Partial::new(n_net)))
            .collect();
    }

    /// Re-size the persistent worker pool (e.g. `--workers` on a
    /// resumed run, where the backend is built from the artifact
    /// before the flag applies). The shard plan is untouched: the
    /// worker count never changes the reduction order, only how many
    /// threads claim shards.
    pub fn set_workers(&mut self, workers: usize) -> Result<()> {
        ensure!(workers > 0,
                "--workers must be a positive integer, got 0");
        let n = workers.min(self.ne.max(1));
        if n == self.n_threads {
            return Ok(());
        }
        self.n_threads = n;
        self.pool = WorkerPool::new(n)?;
        let bp = self.block_elems * self.nq;
        let jrows = self.block_elems * self.nt;
        self.worker_ws = (0..n)
            .map(|_| Mutex::new(Workspace::new(&self.net, bp, jrows)))
            .collect();
        Ok(())
    }

    /// Test hook: force a block size to exercise ragged block edges.
    #[cfg(test)]
    fn set_block_elems(&mut self, be: usize) {
        self.block_elems = be.max(1);
        self.rebuild_workspaces();
    }

    /// Trainable parameter count (network + eps slot when present).
    pub fn n_opt_params(&self) -> usize {
        self.m.len()
    }

    /// Effective worker-thread count (the configured `--workers` /
    /// env / machine parallelism, clamped to the element count) — what
    /// a timing record should report.
    pub fn n_threads(&self) -> usize {
        self.n_threads
    }

    /// The live network (e.g. for prediction-only timing runs).
    pub fn network(&self) -> &Mlp {
        &self.net
    }

    fn trainable_eps(&self) -> bool {
        self.cfg.loss == NativeLoss::InverseConst
    }

    /// Flat view of the optimized parameters (tests / diagnostics).
    pub fn params_flat(&self) -> Vec<f64> {
        let mut out = self.net.theta.clone();
        if self.trainable_eps() {
            out.push(self.eps);
        }
        out
    }

    /// Overwrite the optimized parameters from a flat vector (tests /
    /// diagnostics; checkpoints restore via
    /// [`NativeBackend::load_checkpoint`] instead).
    pub fn set_params_flat(&mut self, theta: &[f64]) -> Result<()> {
        ensure!(theta.len() == self.n_opt_params(),
                "expected {} params, got {}", self.n_opt_params(),
                theta.len());
        let n_net = self.net.n_params();
        self.net.theta.copy_from_slice(&theta[..n_net]);
        if self.trainable_eps() {
            self.eps = theta[n_net];
        }
        Ok(())
    }

    /// Restore network parameters, trainable eps and Adam state from a
    /// parsed artifact, after verifying the checkpoint describes *this*
    /// backend: same loss mode, same network shape, same domain
    /// fingerprint and same hoisted weak-form coefficients. Every
    /// mismatch is a clear error, never a silently different run.
    pub fn load_checkpoint(&mut self, ck: &Checkpoint) -> Result<()> {
        ensure!(
            ck.loss_mode == self.cfg.loss.mode_str(),
            "checkpoint was trained with loss mode '{}' but this \
             backend runs '{}'",
            ck.loss_mode,
            self.cfg.loss.mode_str()
        );
        ensure!(
            ck.layers == self.cfg.layers
                && ck.two_head == self.net.two_head(),
            "checkpoint network {:?} (two_head: {}) does not match the \
             configured {:?} (two_head: {})",
            ck.layers,
            ck.two_head,
            self.cfg.layers,
            self.net.two_head()
        );
        ensure!(
            ck.fingerprint == self.fingerprint,
            "checkpoint domain fingerprint does not match this run \
             (checkpoint: ne={} nt={} nq={} points={}, here: ne={} \
             nt={} nq={} points={}) — rebuild with the same mesh kind, \
             --n, --nt1d and --nq1d the checkpoint was trained on",
            ck.fingerprint.ne,
            ck.fingerprint.nt,
            ck.fingerprint.nq,
            ck.fingerprint.n_points,
            self.fingerprint.ne,
            self.fingerprint.nt,
            self.fingerprint.nq,
            self.fingerprint.n_points
        );
        ensure!(
            ck.form == self.form,
            "checkpoint weak-form coefficients differ from problem \
             '{}''s — resume with the same --problem and the same \
             coefficient flags (e.g. --k-pi)",
            self.problem_label
        );
        let here = TrainHyper {
            tau: self.tau,
            gamma: self.gamma,
            seed: self.seed,
            eps_init: self.eps_init,
            nb: self.cfg.nb,
            ns: self.cfg.ns,
        };
        ensure!(
            ck.hyper == here,
            "checkpoint hyper-parameters {:?} do not match this \
             backend's {:?} — build the backend with the artifact's \
             values (NativeBackend::from_checkpoint does this) so the \
             resumed objective and boundary/sensor samples are \
             identical",
            ck.hyper,
            here
        );
        ensure!(
            ck.theta.len() == self.net.theta.len()
                && ck.adam_m.len() == self.m.len()
                && ck.adam_v.len() == self.v.len(),
            "checkpoint parameter/optimizer sizes ({}, {}, {}) do not \
             match this backend ({}, {}, {})",
            ck.theta.len(),
            ck.adam_m.len(),
            ck.adam_v.len(),
            self.net.theta.len(),
            self.m.len(),
            self.v.len()
        );
        self.net.theta.copy_from_slice(&ck.theta);
        self.eps = ck.eps;
        self.m.copy_from_slice(&ck.adam_m);
        self.v.copy_from_slice(&ck.adam_v);
        Ok(())
    }

    /// Build a backend from a checkpoint + the (re-assembled) data it
    /// was trained on: network shape, loss mode and scalar hyper-
    /// parameters come from the artifact, the mesh/domain from `src` —
    /// then [`NativeBackend::load_checkpoint`] verifies they agree and
    /// restores the trained state. The warm-restart entry point of
    /// `repro train --resume`.
    pub fn from_checkpoint(ck: &Checkpoint, src: &DataSource<'_>)
        -> Result<NativeBackend> {
        let cfg = NativeConfig {
            layers: ck.layers.clone(),
            loss: NativeLoss::from_mode_str(&ck.loss_mode)?,
            nb: ck.hyper.nb,
            ns: ck.hyper.ns,
        };
        let opts = BackendOpts {
            tau: ck.hyper.tau,
            gamma: ck.hyper.gamma,
            seed: ck.hyper.seed,
            eps_init: ck.hyper.eps_init,
            // the worker count is run-ephemeral, not trained state:
            // resolve from env/machine here, [`set_workers`] after
            workers: None,
        };
        let mut backend = NativeBackend::new(&cfg, src, &opts)?;
        backend.load_checkpoint(ck)?;
        Ok(backend)
    }

    /// Full objective + flat gradient at the current parameters (public
    /// for gradient-check tests; `step` wraps this with Adam). The
    /// returned vector is a copy of the internal reused buffer.
    pub fn loss_and_grad(&mut self) -> Result<(StepStats, Vec<f64>)> {
        let stats = self.compute_loss_grad()?;
        Ok((stats, self.grad.clone()))
    }

    /// The tensorized step objective: fills `self.grad` and returns the
    /// loss components. One coordinator tick — the four phases run in
    /// order on the persistent pool; no allocation on this path.
    fn compute_loss_grad(&mut self) -> Result<StepStats> {
        let n_net = self.net.n_params();
        let n_shards = self.plan.n_shards();
        // per-phase telemetry: inert (no clock reads) unless a metrics
        // stream is armed; the trainer collects the published times
        // when it emits the step event
        let mut pclock = crate::telemetry::PhaseClock::start();

        // ---- AssignShards: reset the per-shard accumulators. The
        // plan itself is step-invariant (a function of ne/nq/
        // block_elems fixed at construction), so assignment is zeroing
        // the partials the workers are about to claim.
        self.tick.begin(Phase::AssignShards)?;
        for p in &mut self.shard_partials {
            ride_mut(p).reset();
        }
        pclock.mark(0);

        // ---- Step: workers pull shards off a shared cursor. Results
        // are keyed by *shard*, not by worker, so scheduling noise
        // (which worker got which shard, in what order) cannot change
        // a single bit downstream. Idle workers (n_shards < workers)
        // see an exhausted cursor and park again immediately.
        self.tick.begin(Phase::Step)?;
        {
            let this: &NativeBackend = self;
            let cursor = AtomicUsize::new(0);
            this.pool.run(&|wid| {
                let mut ws = ride(&this.worker_ws[wid]);
                loop {
                    let s = cursor.fetch_add(1, Ordering::Relaxed);
                    if s >= n_shards {
                        break;
                    }
                    let sh = this.plan.shard(s);
                    let mut part = ride(&this.shard_partials[s]);
                    this.element_range(sh.lo, sh.hi, &mut ws,
                                       &mut part);
                }
            })?;
        }
        pclock.mark(1);

        // ---- Reduce: pairwise tree over the fixed shard order. The
        // pairing depends only on the shard count and pairs within a
        // level are disjoint, so any worker interleaving produces the
        // same sums — per-step losses are bit-identical for any
        // --workers value.
        self.tick.begin(Phase::Reduce)?;
        {
            let this: &NativeBackend = self;
            let mut stride = 1;
            while stride < n_shards {
                let np = shard::n_pairs(n_shards, stride);
                let cursor = AtomicUsize::new(0);
                this.pool.run(&|_wid| loop {
                    let k = cursor.fetch_add(1, Ordering::Relaxed);
                    if k >= np {
                        break;
                    }
                    let (a, b) = shard::pair(stride, k);
                    // a < b and no two pairs of a level share a shard:
                    // the lock order is fixed and contention-free
                    let mut pa = ride(&this.shard_partials[a]);
                    let pb = ride(&this.shard_partials[b]);
                    pa.merge(&pb);
                })?;
                stride *= 2;
            }
        }
        pclock.mark(2);

        // ---- Sync: fold the root shard into the flat gradient, then
        // the penalty passes (single-threaded on worker 0's workspace
        // — a worker-count-independent tail) and the step stats.
        self.tick.begin(Phase::Sync)?;
        self.grad.fill(0.0);
        let mut var_sq = 0.0;
        let mut geps = 0.0;
        if let Some(cell) = self.shard_partials.first_mut() {
            let root = ride_mut(cell);
            self.grad[..n_net].copy_from_slice(&root.grad);
            var_sq = root.var_sq;
            geps = root.geps;
        }
        let var_loss = var_sq / (self.ne * self.nt) as f64;

        // ---- Dirichlet penalty, blocked through the batched kernels
        let nb = self.bd_u.len();
        let ws0 = ride_mut(&mut self.worker_ws[0]);
        let bd_sq = penalty_pass(&self.net, ws0,
                                 &mut self.grad[..n_net], &self.bd_flat,
                                 &self.bd_u, self.tau);
        let bd_loss = bd_sq / nb as f64;

        // ---- sensor penalty (inverse losses), same blocked path
        let mut sensor_loss = 0.0;
        let ns = self.sensor_u.len();
        if ns > 0 {
            let s_sq = penalty_pass(&self.net, ws0,
                                    &mut self.grad[..n_net],
                                    &self.sensor_flat, &self.sensor_u,
                                    self.gamma);
            sensor_loss = s_sq / ns as f64;
        }

        if self.trainable_eps() {
            self.grad[n_net] = geps;
        }

        let loss = var_loss + self.tau * bd_loss + self.gamma * sensor_loss;
        let extra = if self.trainable_eps() {
            self.eps
        } else {
            sensor_loss
        };
        // L2 norm over the fully-assembled flat gradient (network +
        // eps slot): the coordinator's divergence sentinel — one pass
        // over ~n_params values, negligible next to the contraction
        let grad_norm =
            self.grad.iter().map(|g| g * g).sum::<f64>().sqrt();
        pclock.mark(3);
        pclock.finish();
        Ok(StepStats { loss, var_loss, bd_loss, extra, grad_norm })
    }

    /// How the diffusion coefficient enters the contraction: `Some(s)`
    /// is the scalar fast path (folded into GEMV alphas — the
    /// pre-form closed form), `None` means a per-point field (the
    /// form's eps table, or the network head on `InverseSpace`).
    fn eps_scale(&self) -> Option<f64> {
        match self.cfg.loss {
            NativeLoss::InverseConst => Some(self.eps),
            NativeLoss::InverseSpace => None,
            NativeLoss::Forward => self.form.eps.constant(),
        }
    }

    /// One shard's step body (runs on the persistent pool): batched
    /// forward over the shard's element blocks, the generalized
    /// blocked residual contraction, the backward seeds, then one
    /// batched reverse pass per block. `lo` is block-grid aligned by
    /// the shard plan, so the tiling — and therefore every
    /// floating-point operation — is identical to a single-worker
    /// sweep over the same elements.
    fn element_range(
        &self,
        lo: usize,
        hi: usize,
        ws: &mut Workspace,
        partial: &mut Partial,
    ) {
        let nq = self.nq;
        let space = self.cfg.loss == NativeLoss::InverseSpace;
        let be = self.block_elems;
        for blk in (lo..hi).step_by(be) {
            let bhi = (blk + be).min(hi);
            let npts = (bhi - blk) * nq;
            let pts = &self.quad_xy[2 * blk * nq..2 * bhi * nq];
            self.net.forward_block(ws, pts, npts, space);
            self.block_residual(ws, blk, bhi, partial);
            self.block_seeds(ws, blk, bhi);
            self.net.backward_block(ws, &mut partial.grad, pts, npts,
                                    space);
        }
    }

    /// The generalized residual of one element block (forward tapes
    /// already in `ws`):
    /// `r[e,j] = sum_q eps_q (Gx ux + Gy uy) + sum_q V (b_q.grad u +
    /// c_q u) - F`. Constant eps folds into the products as a scalar
    /// (identical operations to the pre-form closed form); per-point
    /// eps (table or network head) scales the tangents first — the
    /// same blocked GEMVs either way. Accumulates `var_sq` and, on the
    /// trainable-scalar mode, `geps` into `partial`.
    fn block_residual(
        &self,
        ws: &mut Workspace,
        blk: usize,
        bhi: usize,
        partial: &mut Partial,
    ) {
        let (nt, nq) = (self.nt, self.nq);
        let cr = 2.0 / (self.ne * nt) as f64;
        let nbl = bhi - blk;
        let npts = nbl * nq;
        let p0 = blk * nq;
        let space = self.cfg.loss == NativeLoss::InverseSpace;
        let eps_scale = self.eps_scale();
        let conv = self.form.has_convection();
        let reac = self.form.has_reaction();
        // V-contracted point values: convection + reaction share one
        // product against the V slab
        if conv || reac {
            for p in 0..npts {
                let gp = p0 + p;
                let mut v = 0.0;
                if conv {
                    v += self.form.bx.at(gp) * ws.ux[p]
                        + self.form.by.at(gp) * ws.uy[p];
                }
                if reac {
                    v += self.form.c.at(gp) * ws.u[p];
                }
                ws.dq[p] = v;
            }
        }
        // per-point diffusion fields fold into the tangents
        if eps_scale.is_none() {
            if space {
                for p in 0..npts {
                    ws.uxs[p] = ws.epsv[p] * ws.ux[p];
                    ws.uys[p] = ws.epsv[p] * ws.uy[p];
                }
            } else {
                for p in 0..npts {
                    let e = self.form.eps.at(p0 + p);
                    ws.uxs[p] = e * ws.ux[p];
                    ws.uys[p] = e * ws.uy[p];
                }
            }
        }
        let escale = eps_scale.unwrap_or(1.0);
        let track_geps = self.trainable_eps();
        for ei in 0..nbl {
            let e = blk + ei;
            let gbase = e * nt * nq;
            let slab = gbase..gbase + nt * nq;
            let pr = ei * nq..(ei + 1) * nq;
            let jr = ei * nt..(ei + 1) * nt;
            let (tx, ty): (&[f64], &[f64]) = if eps_scale.is_none() {
                (&ws.uxs[pr.clone()], &ws.uys[pr.clone()])
            } else {
                (&ws.ux[pr.clone()], &ws.uy[pr.clone()])
            };
            gemv(nt, nq, 1.0, &self.gx[slab.clone()], false, tx, 0.0,
                 &mut ws.cvals[jr.clone()]);
            gemv(nt, nq, 1.0, &self.gy[slab.clone()], false, ty, 1.0,
                 &mut ws.cvals[jr.clone()]);
            if conv || reac {
                gemv(nt, nq, 1.0, &self.vmat[slab], false, &ws.dq[pr],
                     0.0, &mut ws.resid[jr.clone()]);
            } else {
                ws.resid[jr.clone()].fill(0.0);
            }
            let fb = e * nt;
            for j in 0..nt {
                let c = ws.cvals[ei * nt + j];
                let r = escale * c + ws.resid[ei * nt + j]
                    - self.f_mat[fb + j];
                ws.resid[ei * nt + j] = r;
                partial.var_sq += r * r;
                // on the trainable-scalar mode `c` is the pre-eps
                // contraction, so this is exactly dL/deps
                if track_geps {
                    partial.geps += cr * r * c;
                }
            }
        }
    }

    /// Backward seeds of one block from the residuals in `ws.resid`:
    /// `seed_x/seed_y = eps_q (cr Gx^T r / cr Gy^T r) + b_q (cr V^T r)`,
    /// `seed_u = c_q (cr V^T r)` (the reaction adjoint), and on the
    /// two-head mode the field adjoint
    /// `seed_e = (cr Gx^T r) ux + (cr Gy^T r) uy` per quadrature point.
    fn block_seeds(&self, ws: &mut Workspace, blk: usize, bhi: usize) {
        let (nt, nq) = (self.nt, self.nq);
        let cr = 2.0 / (self.ne * nt) as f64;
        let nbl = bhi - blk;
        let npts = nbl * nq;
        let p0 = blk * nq;
        let space = self.cfg.loss == NativeLoss::InverseSpace;
        let eps_scale = self.eps_scale();
        let conv = self.form.has_convection();
        let reac = self.form.has_reaction();
        let escale = eps_scale.unwrap_or(1.0);
        ws.seed_u[..npts].fill(0.0);
        for ei in 0..nbl {
            let e = blk + ei;
            let gbase = e * nt * nq;
            let slab = gbase..gbase + nt * nq;
            let jr = ei * nt..(ei + 1) * nt;
            let pr = ei * nq..(ei + 1) * nq;
            gemv(nt, nq, cr * escale, &self.gx[slab.clone()], true,
                 &ws.resid[jr.clone()], 0.0, &mut ws.seed_x[pr.clone()]);
            gemv(nt, nq, cr * escale, &self.gy[slab.clone()], true,
                 &ws.resid[jr.clone()], 0.0, &mut ws.seed_y[pr.clone()]);
            if eps_scale.is_none() {
                // seed_x/seed_y hold cr Gx^T r / cr Gy^T r: on the
                // two-head mode combine them into the field adjoint,
                // then scale by the per-point eps for the tangent
                // pull-back
                if space {
                    for p in pr.clone() {
                        ws.seed_e[p] = ws.seed_x[p] * ws.ux[p]
                            + ws.seed_y[p] * ws.uy[p];
                        ws.seed_x[p] *= ws.epsv[p];
                        ws.seed_y[p] *= ws.epsv[p];
                    }
                } else {
                    for p in pr.clone() {
                        let epq = self.form.eps.at(p0 + p);
                        ws.seed_x[p] *= epq;
                        ws.seed_y[p] *= epq;
                    }
                }
            }
            if conv || reac {
                gemv(nt, nq, cr, &self.vmat[slab], true,
                     &ws.resid[jr], 0.0, &mut ws.tv[pr.clone()]);
                for p in pr {
                    let gp = p0 + p;
                    let tv = ws.tv[p];
                    if conv {
                        ws.seed_x[p] += self.form.bx.at(gp) * tv;
                        ws.seed_y[p] += self.form.by.at(gp) * tv;
                    }
                    if reac {
                        ws.seed_u[p] = self.form.c.at(gp) * tv;
                    }
                }
            }
        }
    }

    /// Test hook: run the forward + residual contraction sequentially
    /// and collect `r[e,j]` for every element — the regression surface
    /// the closed-form bit-for-bit property test compares against.
    #[cfg(test)]
    fn residuals_for_test(&self) -> Vec<f64> {
        let (nt, nq, be) = (self.nt, self.nq, self.block_elems);
        let space = self.cfg.loss == NativeLoss::InverseSpace;
        let mut out = vec![0.0; self.ne * nt];
        // a scratch workspace keeps the borrow checker away from the
        // shared per-worker cells; test-only, so the allocation is fine
        let mut ws = Workspace::new(&self.net, be * nq, be * nt);
        let mut partial = Partial::new(self.net.n_params());
        for blk in (0..self.ne).step_by(be) {
            let bhi = (blk + be).min(self.ne);
            let npts = (bhi - blk) * nq;
            let pts = &self.quad_xy[2 * blk * nq..2 * bhi * nq];
            self.net.forward_block(&mut ws, pts, npts, space);
            self.block_residual(&mut ws, blk, bhi, &mut partial);
            out[blk * nt..bhi * nt]
                .copy_from_slice(&ws.resid[..(bhi - blk) * nt]);
        }
        out
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn loss_kind(&self) -> &str {
        self.kind
    }

    fn step(&mut self, step: usize, lr: f64) -> Result<StepStats> {
        ensure!(step >= 1, "step is 1-based");
        // chaos tier: a simulated AVX2 kernel fault degrades dispatch
        // to the scalar ground-truth kernels for the rest of the
        // process — training continues, bit-identical from here on to
        // a scalar run resumed from the same state
        if crate::runtime::failpoint::fired("kernel.avx2.fault") {
            crate::linalg::simd::degrade_to_scalar(
                "injected AVX2 fault (failpoint kernel.avx2.fault)",
            );
        }
        let mut stats = self.compute_loss_grad()?;
        // chaos tier: poison the gradient *before* the Adam update so
        // the NaN propagates into m/v/theta exactly like a real
        // divergence — the coordinator's rollback must repair all of it
        if crate::runtime::failpoint::fired("grad.nan") {
            self.grad.fill(f64::NAN);
            stats.loss = f64::NAN;
            stats.grad_norm = f64::NAN;
        }
        // Adam
        const B1: f64 = 0.9;
        const B2: f64 = 0.999;
        const EPS: f64 = 1e-8;
        let bc1 = 1.0 - B1.powi(step as i32);
        let bc2 = 1.0 - B2.powi(step as i32);
        let n_net = self.net.n_params();
        for i in 0..self.grad.len() {
            let g = self.grad[i];
            self.m[i] = B1 * self.m[i] + (1.0 - B1) * g;
            self.v[i] = B2 * self.v[i] + (1.0 - B2) * g * g;
            let update =
                lr * (self.m[i] / bc1) / ((self.v[i] / bc2).sqrt() + EPS);
            if i < n_net {
                self.net.theta[i] -= update;
            } else {
                self.eps -= update;
            }
        }
        // report the post-update eps, matching the XLA backend (which
        // reads eps back from the updated device state)
        if self.trainable_eps() {
            stats.extra = self.eps;
        }
        Ok(stats)
    }

    fn predict(&self, points: &[[f64; 2]]) -> Result<Vec<Vec<f32>>> {
        let (u, eps) = self.net.eval_heads(points);
        Ok(match eps {
            Some(e) => vec![u, e],
            None => vec![u],
        })
    }

    fn predict_eps_field(&self, points: &[[f64; 2]])
        -> Result<Option<Vec<f32>>> {
        Ok(self.net.eval_heads(points).1)
    }

    fn export_checkpoint(&self) -> Result<Checkpoint> {
        // run-level metadata (registry id, CLI flags, step count) is
        // the coordinator's to fill in — the backend does not know it
        Ok(Checkpoint {
            problem: String::new(),
            problem_label: self.problem_label.clone(),
            loss_mode: self.cfg.loss.mode_str().to_string(),
            loss_kind: self.kind.to_string(),
            cli: Vec::new(),
            layers: self.cfg.layers.clone(),
            two_head: self.net.two_head(),
            step: 0,
            best_metric: None,
            theta: self.net.theta.clone(),
            eps: self.eps,
            adam_m: self.m.clone(),
            adam_v: self.v.clone(),
            form: self.form.clone(),
            fingerprint: self.fingerprint.clone(),
            hyper: TrainHyper {
                tau: self.tau,
                gamma: self.gamma,
                seed: self.seed,
                eps_init: self.eps_init,
                nb: self.cfg.nb,
                ns: self.cfg.ns,
            },
        })
    }

    fn current_eps(&self) -> Option<f64> {
        if self.trainable_eps() {
            Some(self.eps)
        } else {
            None
        }
    }

    fn restore_checkpoint(&mut self, ck: &Checkpoint) -> Result<()> {
        // the same verify-then-restore path `--resume` uses; from a
        // snapshot of this very backend every check passes trivially
        self.load_checkpoint(ck)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autodiff::Dual2;
    use crate::fem::assembly;
    use crate::fem::quadrature::QuadKind;
    use crate::mesh::generators;
    use crate::problems::{CoeffVariability, PoissonSin, Problem};

    /// Scratch problem for gradchecks: any combination of constant
    /// eps/b/c, each optionally promoted to a spatially-varying field
    /// via the variability flags (the varying fields perturb the
    /// constants so the tables are genuinely non-constant).
    struct TestProblem {
        eps: f64,
        b: (f64, f64),
        c: f64,
        var: CoeffVariability,
    }

    impl TestProblem {
        fn constant(eps: f64, b: (f64, f64), c: f64) -> TestProblem {
            TestProblem { eps, b, c, var: CoeffVariability::CONST }
        }
    }

    impl Problem for TestProblem {
        fn name(&self) -> &str {
            "test_problem"
        }
        fn forcing(&self, x: f64, y: f64) -> f64 {
            x.sin() * y.cos() + 0.5
        }
        fn boundary(&self, x: f64, y: f64) -> f64 {
            self.exact(x, y).unwrap()
        }
        fn exact(&self, x: f64, y: f64) -> Option<f64> {
            Some((1.3 * x).sin() * (0.7 * y).cos())
        }
        fn eps(&self) -> f64 {
            self.eps
        }
        fn b(&self) -> (f64, f64) {
            self.b
        }
        fn c(&self) -> f64 {
            self.c
        }
        fn eps_at(&self, x: f64, y: f64) -> f64 {
            if self.var.eps {
                self.eps * (1.0 + 0.3 * (x + y).sin())
            } else {
                self.eps
            }
        }
        fn b_at(&self, x: f64, y: f64) -> (f64, f64) {
            if self.var.b {
                (self.b.0 + 0.2 * y.cos(), self.b.1 + 0.3 * x.sin())
            } else {
                self.b
            }
        }
        fn c_at(&self, x: f64, y: f64) -> f64 {
            if self.var.c {
                self.c + 0.2 * (x * y).cos()
            } else {
                self.c
            }
        }
        fn coeff_variability(&self) -> CoeffVariability {
            self.var
        }
    }

    fn build_backend(
        mesh_n: usize,
        layers: &[usize],
        loss: NativeLoss,
        nb: usize,
        ns: usize,
        problem: &dyn Problem,
    ) -> NativeBackend {
        let mesh = generators::unit_square(mesh_n);
        let dom = assembly::assemble(&mesh, 2, 3, QuadKind::GaussLegendre);
        let src = DataSource {
            mesh: &mesh,
            domain: Some(&dom),
            problem,
            sensor_values: None,
        };
        let cfg = NativeConfig { layers: layers.to_vec(), loss, nb, ns };
        NativeBackend::new(&cfg, &src, &BackendOpts::default()).unwrap()
    }

    fn tiny_backend(loss: NativeLoss, ns: usize) -> NativeBackend {
        tiny_backend_nb(loss, ns, 8)
    }

    fn tiny_backend_nb(
        loss: NativeLoss,
        ns: usize,
        nb: usize,
    ) -> NativeBackend {
        let problem = PoissonSin::new(std::f64::consts::PI);
        build_backend(1, &[2, 4, 1], loss, nb, ns, &problem)
    }

    /// `ln(1 + e^z)` on Dual2 with the same branch structure as the
    /// scalar `softplus`, so reference and implementation agree to
    /// roundoff.
    fn softplus_dual(z: Dual2) -> Dual2 {
        if z.v > 30.0 {
            z
        } else {
            (z.exp() + Dual2::con(1.0)).ln()
        }
    }

    /// Reference loss with Dual2 parameters: recomputes the exact same
    /// objective as `loss_and_grad` (all three loss families, incl. the
    /// two-head inverse-space residual), but with parameter `k` as the
    /// active Dual2 variable, so `.d1` is the exact dLoss/dtheta_k.
    fn loss_dual(b: &NativeBackend, k: usize) -> Dual2 {
        let theta = b.params_flat();
        let p = |i: usize| -> Dual2 {
            if i == k {
                Dual2::var(theta[i])
            } else {
                Dual2::con(theta[i])
            }
        };
        let n_net = b.net.n_params();
        let space = b.cfg.loss == NativeLoss::InverseSpace;
        let inv_const = b.trainable_eps();
        let eps_d = if inv_const {
            p(n_net)
        } else {
            Dual2::con(0.0) // unused: form or head supplies eps
        };
        let wmax = b.net.max_width();
        // forward with tangent-carrying Dual2 arithmetic; the last
        // hidden activation feeds both heads
        let fwd = |x: f64, y: f64| -> (Dual2, Dual2, Dual2, Dual2) {
            let zero = Dual2::con(0.0);
            let mut a = vec![zero; wmax];
            let mut ax = vec![zero; wmax];
            let mut ay = vec![zero; wmax];
            a[0] = Dual2::con(x);
            a[1] = Dual2::con(y);
            ax[0] = Dual2::con(1.0);
            ay[1] = Dual2::con(1.0);
            let last = b.net.n_stages() - 1;
            for l in 0..last {
                let (nin, nout) = (b.net.layers[l], b.net.layers[l + 1]);
                let (w_off, b_off) = b.net.offsets[l];
                let mut na = vec![zero; wmax];
                let mut nax = vec![zero; wmax];
                let mut nay = vec![zero; wmax];
                for j in 0..nout {
                    let mut z = p(b_off + j);
                    let mut zx = zero;
                    let mut zy = zero;
                    for i in 0..nin {
                        let w = p(w_off + i * nout + j);
                        z = z + a[i] * w;
                        zx = zx + ax[i] * w;
                        zy = zy + ay[i] * w;
                    }
                    let t = z.tanh();
                    let s = Dual2::con(1.0) - t * t;
                    na[j] = t;
                    nax[j] = s * zx;
                    nay[j] = s * zy;
                }
                a = na;
                ax = nax;
                ay = nay;
            }
            let nin = b.net.layers[last];
            let (w_off, b_off) = b.net.offsets[last];
            let mut u = p(b_off);
            let mut ux = zero;
            let mut uy = zero;
            for i in 0..nin {
                let w = p(w_off + i);
                u = u + a[i] * w;
                ux = ux + ax[i] * w;
                uy = uy + ay[i] * w;
            }
            let eps = match b.net.eps_head {
                Some((we_off, be_off)) => {
                    let mut z = p(be_off);
                    for i in 0..nin {
                        z = z + a[i] * p(we_off + i);
                    }
                    softplus_dual(z)
                }
                None => zero,
            };
            (u, ux, uy, eps)
        };

        let (ne, nt, nq) = (b.ne, b.nt, b.nq);
        let mut var = Dual2::con(0.0);
        for e in 0..ne {
            let mut uv = Vec::with_capacity(nq);
            let mut ux = Vec::with_capacity(nq);
            let mut uy = Vec::with_capacity(nq);
            let mut epsq = Vec::with_capacity(nq);
            for q in 0..nq {
                let x = b.quad_xy[2 * (e * nq + q)];
                let y = b.quad_xy[2 * (e * nq + q) + 1];
                let (u, dx, dy, ep) = fwd(x, y);
                uv.push(u);
                ux.push(dx);
                uy.push(dy);
                epsq.push(ep);
            }
            for j in 0..nt {
                let base = (e * nt + j) * nq;
                let mut r = -Dual2::con(b.f_mat[e * nt + j]);
                for q in 0..nq {
                    let gp = e * nq + q;
                    let g = ux[q] * b.gx[base + q] + uy[q] * b.gy[base + q];
                    // eps per point: head field (two-head), trainable
                    // scalar (inverse_const) or the hoisted form
                    let ep = if space {
                        epsq[q]
                    } else if inv_const {
                        eps_d
                    } else {
                        Dual2::con(b.form.eps.at(gp))
                    };
                    let conv = (ux[q] * b.form.bx.at(gp)
                        + uy[q] * b.form.by.at(gp))
                        * b.vmat[base + q];
                    let reac =
                        uv[q] * (b.form.c.at(gp) * b.vmat[base + q]);
                    r = r + ep * g + conv + reac;
                }
                var = var + r * r;
            }
        }
        var = var * (1.0 / (ne * nt) as f64);

        let mut bd = Dual2::con(0.0);
        for (i, pt) in b.bd_flat.chunks_exact(2).enumerate() {
            let (u, _, _, _) = fwd(pt[0], pt[1]);
            let d = u - Dual2::con(b.bd_u[i]);
            bd = bd + d * d;
        }
        bd = bd * (1.0 / b.bd_u.len() as f64);

        let mut sens = Dual2::con(0.0);
        if !b.sensor_u.is_empty() {
            for (i, pt) in b.sensor_flat.chunks_exact(2).enumerate() {
                let (u, _, _, _) = fwd(pt[0], pt[1]);
                let d = u - Dual2::con(b.sensor_u[i]);
                sens = sens + d * d;
            }
            sens = sens * (1.0 / b.sensor_u.len() as f64);
        }

        var + bd * b.tau + sens * b.gamma
    }

    fn check_grad(b: &mut NativeBackend, tol: f64) {
        let (stats, grad) = b.loss_and_grad().unwrap();
        let l_ref = loss_dual(b, 0).v;
        assert!(
            (stats.loss - l_ref).abs() <= 1e-10 * (1.0 + l_ref.abs()),
            "loss mismatch: {} vs Dual2 {}", stats.loss, l_ref
        );
        for k in 0..b.n_opt_params() {
            let want = loss_dual(b, k).d1;
            let got = grad[k];
            let denom = 1.0 + want.abs().max(got.abs());
            assert!(
                ((got - want) / denom).abs() < tol,
                "param {k}: backprop {got} vs Dual2 {want}"
            );
        }
    }

    #[test]
    fn backprop_matches_dual2_poisson() {
        let mut b = tiny_backend(NativeLoss::Forward, 0);
        assert_eq!(b.loss_kind(), "poisson");
        check_grad(&mut b, 1e-10);
    }

    #[test]
    fn gradcheck_holds_with_simd_epilogue_active() {
        // Explicit satellite check: with the AVX2 tanh epilogue in the
        // forward pass, backprop must still match Dual2 (which runs on
        // libm tanh) — the 1e-15-class vector-tanh error sits far
        // below the 1e-10 gradcheck tolerance. Under
        // REPRO_FORCE_SCALAR=1 (or without AVX2) the epilogue *is*
        // libm tanh and the other gradchecks already cover it.
        if simd::active() != simd::Kernel::Avx2 {
            eprintln!("skipping: SIMD kernel not active on this host");
            return;
        }
        let mut b = tiny_backend(NativeLoss::Forward, 0);
        check_grad(&mut b, 1e-10);
    }

    #[test]
    fn backprop_matches_dual2_convection() {
        let p = TestProblem::constant(0.7, (0.3, -0.2), 0.0);
        let mut b =
            build_backend(1, &[2, 4, 1], NativeLoss::Forward, 8, 0, &p);
        assert_eq!(b.loss_kind(), "cd");
        check_grad(&mut b, 1e-10);
    }

    #[test]
    fn backprop_matches_dual2_reaction_helmholtz() {
        // constant reaction c = -k^2: the Helmholtz mass term through
        // the V premultiplier
        let p = TestProblem::constant(1.0, (0.0, 0.0), -6.25);
        let mut b =
            build_backend(1, &[2, 4, 1], NativeLoss::Forward, 8, 0, &p);
        assert_eq!(b.loss_kind(), "helmholtz");
        check_grad(&mut b, 1e-10);
    }

    #[test]
    fn backprop_matches_dual2_variable_convection() {
        let p = TestProblem {
            eps: 0.8,
            b: (0.4, -0.3),
            c: 0.0,
            var: CoeffVariability { eps: false, b: true, c: false },
        };
        let mut b =
            build_backend(1, &[2, 4, 1], NativeLoss::Forward, 8, 0, &p);
        check_grad(&mut b, 1e-10);
    }

    #[test]
    fn backprop_matches_dual2_variable_eps_forward() {
        // a *fixed* eps(x,y) table on the forward mode: same tangent
        // scaling as the two-head path, no field adjoint
        let p = TestProblem {
            eps: 1.2,
            b: (0.0, 0.0),
            c: 0.0,
            var: CoeffVariability { eps: true, b: false, c: false },
        };
        let mut b =
            build_backend(1, &[2, 4, 1], NativeLoss::Forward, 8, 0, &p);
        check_grad(&mut b, 1e-10);
    }

    #[test]
    fn backprop_matches_dual2_all_variable_coefficients() {
        // eps/b/c all tabulated at once, reaction included
        let p = TestProblem {
            eps: 0.9,
            b: (0.3, -0.2),
            c: -1.5,
            var: CoeffVariability { eps: true, b: true, c: true },
        };
        let mut b =
            build_backend(2, &[2, 4, 1], NativeLoss::Forward, 12, 0, &p);
        check_grad(&mut b, 1e-10);
    }

    #[test]
    fn backprop_matches_dual2_inverse_eps() {
        let mut b = tiny_backend(NativeLoss::InverseConst, 4);
        check_grad(&mut b, 1e-10);
    }

    #[test]
    fn backprop_matches_dual2_inverse_eps_with_conv_and_reaction() {
        // the trainable scalar eps composes with the form's fixed
        // convection + reaction terms
        let p = TestProblem::constant(0.5, (0.2, -0.1), -0.8);
        let mut b = build_backend(1, &[2, 4, 1], NativeLoss::InverseConst,
                                  8, 4, &p);
        check_grad(&mut b, 1e-10);
    }

    #[test]
    fn backprop_matches_dual2_inverse_space() {
        // full two-head step: trunk, u head, eps head, sensor term,
        // constant convection from the form
        let p = TestProblem::constant(1.0, (1.0, 0.0), 0.0);
        let mut b = build_backend(1, &[2, 4, 1], NativeLoss::InverseSpace,
                                  8, 4, &p);
        assert!(b.net.two_head());
        check_grad(&mut b, 1e-10);
    }

    #[test]
    fn backprop_matches_dual2_inverse_space_no_convection() {
        let mut b = tiny_backend(NativeLoss::InverseSpace, 5);
        check_grad(&mut b, 1e-10);
    }

    #[test]
    fn backprop_matches_dual2_inverse_space_with_reaction_and_var_b() {
        // the eps head composes with a variable convection field and a
        // reaction term: all three seeds (seed_e, scaled seed_x/y,
        // seed_u) live in the same backward pass
        let p = TestProblem {
            eps: 1.0,
            b: (0.5, -0.4),
            c: -1.1,
            var: CoeffVariability { eps: false, b: true, c: true },
        };
        let mut b = build_backend(1, &[2, 4, 1], NativeLoss::InverseSpace,
                                  8, 4, &p);
        check_grad(&mut b, 1e-10);
    }

    #[test]
    fn backprop_matches_dual2_inverse_space_ragged_blocks() {
        // block_elems = 1 on a 4-element mesh forces multiple blocks
        // per chunk; nb = 25 > block_pts forces chunked penalty blocks
        // with the eps head seeds zeroed per block.
        let p = TestProblem::constant(1.0, (0.3, -0.2), 0.0);
        let mut b = build_backend(2, &[2, 4, 1], NativeLoss::InverseSpace,
                                  25, 6, &p);
        b.set_block_elems(1);
        check_grad(&mut b, 1e-10);
    }

    #[test]
    fn backprop_matches_dual2_reaction_ragged_blocks() {
        // variable reaction + convection across ragged single-element
        // blocks: the seed_u reaction adjoint must reset per block
        let p = TestProblem {
            eps: 1.0,
            b: (0.3, -0.2),
            c: -2.0,
            var: CoeffVariability { eps: true, b: true, c: true },
        };
        let mut b =
            build_backend(2, &[2, 4, 1], NativeLoss::Forward, 25, 0, &p);
        b.set_block_elems(1);
        check_grad(&mut b, 1e-10);
    }

    #[test]
    fn backprop_matches_dual2_reaction_one_wide_layers() {
        // 1-wide then 3-wide hidden layers through the reaction and
        // variable-convection adjoints
        let p = TestProblem {
            eps: 0.7,
            b: (0.1, -0.4),
            c: -1.3,
            var: CoeffVariability { eps: false, b: true, c: true },
        };
        let mut b = build_backend(1, &[2, 1, 3, 1], NativeLoss::Forward,
                                  8, 0, &p);
        check_grad(&mut b, 1e-10);
    }

    #[test]
    fn backprop_matches_dual2_inverse_space_one_wide_heads() {
        // 1-wide last hidden layer: both heads read a width-1 trunk
        let p = TestProblem::constant(1.0, (0.1, -0.4), 0.0);
        let mut b = build_backend(1, &[2, 1, 1], NativeLoss::InverseSpace,
                                  8, 3, &p);
        check_grad(&mut b, 1e-10);
    }

    #[test]
    fn backprop_matches_dual2_inverse_space_trunkless() {
        // layers [2, 1]: both heads read the raw (x, y) input — the
        // degenerate l == 0 branch of the head adjoints
        let p = TestProblem::constant(1.0, (1.0, 0.5), 0.0);
        let mut b = build_backend(1, &[2, 1], NativeLoss::InverseSpace,
                                  8, 3, &p);
        check_grad(&mut b, 1e-10);
    }

    #[test]
    fn inverse_space_block_size_invariance() {
        let p = TestProblem::constant(1.0, (1.0, 0.0), 0.0);
        let mk = || {
            build_backend(1, &[2, 4, 1], NativeLoss::InverseSpace, 25, 4,
                          &p)
        };
        let mut b1 = mk();
        let mut b2 = mk();
        b2.set_block_elems(1);
        let (s1, g1) = b1.loss_and_grad().unwrap();
        let (s2, g2) = b2.loss_and_grad().unwrap();
        assert!((s1.loss - s2.loss).abs() < 1e-12 * (1.0 + s1.loss.abs()));
        for (a, b) in g1.iter().zip(&g2) {
            assert!((a - b).abs() < 1e-11 * (1.0 + a.abs()),
                    "grad mismatch across block sizes: {a} vs {b}");
        }
    }

    #[test]
    fn generalized_block_size_invariance() {
        // variable eps/b/c tables must index by *global* quadrature
        // point, not block-local offsets — block retiling is the test
        let p = TestProblem {
            eps: 0.9,
            b: (0.3, -0.2),
            c: -1.5,
            var: CoeffVariability { eps: true, b: true, c: true },
        };
        let mk =
            || build_backend(2, &[2, 4, 1], NativeLoss::Forward, 25, 0, &p);
        let mut b1 = mk();
        let mut b2 = mk();
        b2.set_block_elems(1);
        let (s1, g1) = b1.loss_and_grad().unwrap();
        let (s2, g2) = b2.loss_and_grad().unwrap();
        assert!((s1.loss - s2.loss).abs() < 1e-12 * (1.0 + s1.loss.abs()));
        for (a, b) in g1.iter().zip(&g2) {
            assert!((a - b).abs() < 1e-11 * (1.0 + a.abs()),
                    "grad mismatch across block sizes: {a} vs {b}");
        }
    }

    #[test]
    fn workspaces_and_partials_are_reused_across_steps() {
        // the hot path must not reallocate: every per-worker workspace
        // and per-shard accumulator keeps its address across steps
        let p = TestProblem::constant(1.0, (1.0, 0.0), 0.0);
        let mut b = build_backend(1, &[2, 4, 1], NativeLoss::InverseSpace,
                                  8, 4, &p);
        let ws_ptrs: Vec<(*const f64, *const f64)> = b
            .worker_ws
            .iter_mut()
            .map(|m| {
                let ws = ride_mut(m);
                (ws.u.as_ptr(), ws.epsv.as_ptr())
            })
            .collect();
        let part_ptrs: Vec<*const f64> = b
            .shard_partials
            .iter_mut()
            .map(|m| ride_mut(m).grad.as_ptr())
            .collect();
        let caps: Vec<usize> = b
            .worker_ws
            .iter_mut()
            .map(|m| ride_mut(m).gez.capacity())
            .collect();
        assert!(!part_ptrs.is_empty(), "plan produced no shards");
        for i in 1..=5 {
            b.step(i, 1e-3).unwrap();
        }
        for (m, (pu, pe)) in b.worker_ws.iter_mut().zip(&ws_ptrs) {
            let ws = ride_mut(m);
            assert_eq!(ws.u.as_ptr(), *pu, "workspace reallocated");
            assert_eq!(ws.epsv.as_ptr(), *pe,
                       "eps buffers reallocated");
        }
        for (m, pg) in b.shard_partials.iter_mut().zip(&part_ptrs) {
            assert_eq!(ride_mut(m).grad.as_ptr(), *pg,
                       "shard partial reallocated");
        }
        for (m, c) in b.worker_ws.iter_mut().zip(&caps) {
            assert_eq!(ride_mut(m).gez.capacity(), *c);
        }
    }

    #[test]
    fn losses_and_grads_bitwise_invariant_across_worker_counts() {
        // the tentpole guarantee: the step-invariant shard plan + the
        // fixed-order tree reduce make every per-step loss and the
        // final gradient bit-identical for ANY worker count, including
        // more workers than shards and single-element blocks
        use crate::util::proptest::check_result;
        let avail = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        check_result(
            29,
            6,
            |r| {
                (
                    1 + (r.uniform() * 3.0) as usize, // mesh n in 1..=3
                    r.uniform_in(0.0, 0.2),           // jitter amplitude
                    1 + (r.uniform() * 1000.0) as u64, // net seed
                    r.uniform() < 0.5, // force block_elems = 1
                )
            },
            |&(n, amp, seed, tiny_blocks)| {
                let mesh = generators::skewed_square(n, amp);
                let dom = assembly::assemble(&mesh, 2, 3,
                                             QuadKind::GaussLegendre);
                let p = TestProblem::constant(0.9, (0.4, -0.3), -1.1);
                let src = DataSource {
                    mesh: &mesh,
                    domain: Some(&dom),
                    problem: &p,
                    sensor_values: None,
                };
                let cfg = NativeConfig {
                    layers: vec![2, 4, 1],
                    loss: NativeLoss::Forward,
                    nb: 8,
                    ns: 0,
                };
                let run = |workers: usize| {
                    let opts = BackendOpts {
                        seed,
                        workers: Some(workers),
                        ..BackendOpts::default()
                    };
                    let mut b = NativeBackend::new(&cfg, &src, &opts)
                        .map_err(|e| e.to_string())?;
                    if tiny_blocks {
                        b.set_block_elems(1);
                    }
                    let mut losses = Vec::new();
                    for i in 1..=3 {
                        let s = b
                            .step(i, 1e-3)
                            .map_err(|e| e.to_string())?;
                        losses.push(s.loss.to_bits());
                    }
                    let (_, g) = b
                        .loss_and_grad()
                        .map_err(|e| e.to_string())?;
                    let gbits: Vec<u64> =
                        g.iter().map(|v| v.to_bits()).collect();
                    Ok::<_, String>((losses, gbits))
                };
                let base = run(1)?;
                for w in [2usize, 3, avail] {
                    if run(w)? != base {
                        return Err(format!(
                            "workers={w} diverged from workers=1 \
                             (n={n}, tiny_blocks={tiny_blocks})"
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn more_workers_than_shards_is_harmless() {
        // ne = 1 clamps the pool to one worker; ne = 4 fits one
        // default-sized block, so the lone shard is claimed by one of
        // several workers while the rest park — both must step cleanly
        // and identically to a lone worker
        let p = TestProblem::constant(1.0, (0.2, -0.1), 0.0);
        let run = |mesh_n: usize, workers: usize| {
            let mesh = generators::unit_square(mesh_n);
            let dom = assembly::assemble(&mesh, 2, 3,
                                         QuadKind::GaussLegendre);
            let src = DataSource {
                mesh: &mesh,
                domain: Some(&dom),
                problem: &p,
                sensor_values: None,
            };
            let cfg = NativeConfig {
                layers: vec![2, 4, 1],
                loss: NativeLoss::Forward,
                nb: 8,
                ns: 0,
            };
            let opts = BackendOpts {
                workers: Some(workers),
                ..BackendOpts::default()
            };
            let mut b = NativeBackend::new(&cfg, &src, &opts).unwrap();
            let mut out = 0u64;
            for i in 1..=4 {
                out = b.step(i, 1e-3).unwrap().loss.to_bits();
            }
            out
        };
        assert_eq!(run(1, 1), run(1, 8));
        assert_eq!(run(2, 1), run(2, 8));
    }

    #[test]
    fn workers_zero_is_rejected_with_a_clear_error() {
        let p = TestProblem::constant(1.0, (0.0, 0.0), 0.0);
        let mesh = generators::unit_square(1);
        let dom =
            assembly::assemble(&mesh, 2, 3, QuadKind::GaussLegendre);
        let src = DataSource {
            mesh: &mesh,
            domain: Some(&dom),
            problem: &p,
            sensor_values: None,
        };
        let cfg = NativeConfig {
            layers: vec![2, 4, 1],
            loss: NativeLoss::Forward,
            nb: 8,
            ns: 0,
        };
        let opts =
            BackendOpts { workers: Some(0), ..BackendOpts::default() };
        let err = NativeBackend::new(&cfg, &src, &opts).unwrap_err();
        assert!(err.to_string().contains("positive"), "{err}");
        let mut b = NativeBackend::new(&cfg, &src,
                                       &BackendOpts::default())
            .unwrap();
        let err = b.set_workers(0).unwrap_err();
        assert!(err.to_string().contains("positive"), "{err}");
    }

    #[test]
    fn eval_heads_matches_training_tape() {
        // the prediction-path eps head must agree with the training
        // forward block's epsv tape
        let mlp = Mlp::glorot_two_head(&[2, 6, 4, 1], 11).unwrap();
        let n = 9;
        let mut ws = Workspace::new(&mlp, n, 1);
        let mut rng = Rng::new(5);
        let pts: Vec<f64> =
            (0..2 * n).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
        mlp.forward_block(&mut ws, &pts, n, true);
        let pt_arr: Vec<[f64; 2]> =
            pts.chunks_exact(2).map(|c| [c[0], c[1]]).collect();
        let (u, eps) = mlp.eval_heads(&pt_arr);
        let eps = eps.expect("two-head net must report an eps field");
        for p in 0..n {
            assert!((u[p] as f64 - ws.u[p]).abs() < 1e-6);
            assert!((eps[p] as f64 - ws.epsv[p]).abs() < 1e-6,
                    "eps head mismatch at {p}: {} vs {}", eps[p],
                    ws.epsv[p]);
            assert!(eps[p] > 0.0, "softplus must keep eps positive");
        }
    }

    #[test]
    fn backprop_matches_dual2_with_ragged_blocks() {
        // block_elems = 1 on a 4-element mesh forces multiple blocks per
        // chunk; nb = 25 > block_pts = 9 forces chunked boundary blocks.
        let problem = PoissonSin::new(std::f64::consts::PI);
        let mut b = build_backend(2, &[2, 4, 1], NativeLoss::Forward, 25,
                                  0, &problem);
        b.set_block_elems(1);
        check_grad(&mut b, 1e-10);
    }

    #[test]
    fn backprop_matches_dual2_one_wide_hidden_layer() {
        // odd widths through the GEMM path: a 1-wide then 3-wide net
        let p = TestProblem::constant(1.0, (0.1, -0.4), 0.0);
        let mut b = build_backend(1, &[2, 1, 3, 1], NativeLoss::Forward,
                                  8, 0, &p);
        check_grad(&mut b, 1e-10);
    }

    #[test]
    fn block_size_does_not_change_the_gradient() {
        // same objective, different block tilings: the reductions are
        // reordered, so agreement is to roundoff, not bit-exact
        let mut b1 = tiny_backend_nb(NativeLoss::Forward, 0, 25);
        let mut b2 = tiny_backend_nb(NativeLoss::Forward, 0, 25);
        b2.set_block_elems(1);
        let (s1, g1) = b1.loss_and_grad().unwrap();
        let (s2, g2) = b2.loss_and_grad().unwrap();
        assert!((s1.loss - s2.loss).abs() < 1e-12 * (1.0 + s1.loss.abs()));
        for (a, b) in g1.iter().zip(&g2) {
            assert!((a - b).abs() < 1e-11 * (1.0 + a.abs()),
                    "grad mismatch across block sizes: {a} vs {b}");
        }
    }

    #[test]
    fn forward_block_matches_scalar_reference() {
        for layers in [
            vec![2, 1],
            vec![2, 4, 1],
            vec![2, 3, 5, 1],
            vec![2, 1, 1],
            vec![2, 30, 30, 30, 1],
        ] {
            let mlp = Mlp::glorot(&layers, 7).unwrap();
            let n = 13; // odd on purpose: not a multiple of any tile
            let mut ws = Workspace::new(&mlp, n, 1);
            let mut rng = Rng::new(3);
            let pts: Vec<f64> =
                (0..2 * n).map(|_| rng.uniform_in(-1.0, 1.0)).collect();
            mlp.forward_block(&mut ws, &pts, n, true);
            for p in 0..n {
                let (u, ux, uy) = mlp
                    .forward_point_reference(pts[2 * p], pts[2 * p + 1]);
                assert!((ws.u[p] - u).abs() < 1e-12,
                        "{layers:?} u[{p}]: {} vs {u}", ws.u[p]);
                assert!((ws.ux[p] - ux).abs() < 1e-12,
                        "{layers:?} ux[{p}]: {} vs {ux}", ws.ux[p]);
                assert!((ws.uy[p] - uy).abs() < 1e-12,
                        "{layers:?} uy[{p}]: {} vs {uy}", ws.uy[p]);
            }
        }
    }

    #[test]
    fn step_decreases_loss_on_tiny_problem() {
        let mut b = tiny_backend(NativeLoss::Forward, 0);
        let first = b.step(1, 1e-2).unwrap();
        let mut last = first;
        for i in 2..=100 {
            last = b.step(i, 1e-2).unwrap();
        }
        assert!(last.loss < first.loss,
                "loss did not decrease: {} -> {}", first.loss, last.loss);
    }

    #[test]
    fn deterministic_given_seed() {
        let run = || {
            let mut b = tiny_backend(NativeLoss::Forward, 0);
            let mut out = 0.0;
            for i in 1..=20 {
                out = b.step(i, 1e-3).unwrap().loss;
            }
            out
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn predict_shape_and_determinism() {
        let b = tiny_backend(NativeLoss::Forward, 0);
        let pts = [[0.2, 0.3], [0.8, 0.9]];
        let h = b.predict(&pts).unwrap();
        assert_eq!(h.len(), 1);
        assert_eq!(h[0].len(), 2);
        assert_eq!(b.predict(&pts).unwrap()[0], h[0]);
    }

    #[test]
    fn generalized_contraction_reproduces_closed_form_bit_for_bit() {
        // With constant eps/b and c = 0 the generalized path must take
        // the scalar fast path: the *identical* floating-point
        // operations as the pre-form closed-form residual
        // `r = eps (Gx ux + Gy uy) + V (b . grad u) - F`. The reference
        // transliterates the per-element gemv accumulation order
        // exactly, so the comparison is to the bit, across random
        // jittered meshes, nets and coefficients.
        use crate::util::proptest::check_result;
        check_result(
            17,
            12,
            |r| {
                (
                    1 + (r.uniform() * 3.0) as usize, // mesh n in 1..=3
                    r.uniform_in(0.0, 0.24),          // jitter amplitude
                    r.uniform_in(0.3, 2.0),           // eps
                    r.uniform_in(-0.6, 0.6),          // bx
                    r.uniform_in(-0.6, 0.6),          // by
                    1 + (r.uniform() * 1000.0) as u64, // net seed
                )
            },
            |&(n, amp, eps, bx, by, seed)| {
                let mesh = generators::skewed_square(n, amp);
                let dom = assembly::assemble(&mesh, 2, 3,
                                             QuadKind::GaussLegendre);
                let p = TestProblem::constant(eps, (bx, by), 0.0);
                let src = DataSource {
                    mesh: &mesh,
                    domain: Some(&dom),
                    problem: &p,
                    sensor_values: None,
                };
                let cfg = NativeConfig {
                    layers: vec![2, 5, 1],
                    loss: NativeLoss::Forward,
                    nb: 8,
                    ns: 0,
                };
                let opts = BackendOpts { seed, ..BackendOpts::default() };
                let b = NativeBackend::new(&cfg, &src, &opts).unwrap();
                let got = b.residuals_for_test();

                let (nt, nq, be) = (b.nt, b.nq, b.block_elems);
                let mut ws = Workspace::new(&b.net, be * nq, be * nt);
                let conv = bx != 0.0 || by != 0.0;
                let mut want = vec![0.0; b.ne * nt];
                for blk in (0..b.ne).step_by(be) {
                    let bhi = (blk + be).min(b.ne);
                    let npts = (bhi - blk) * nq;
                    let pts = &b.quad_xy[2 * blk * nq..2 * bhi * nq];
                    b.net.forward_block(&mut ws, pts, npts, false);
                    for ei in 0..bhi - blk {
                        let e = blk + ei;
                        for j in 0..nt {
                            let base = (e * nt + j) * nq;
                            let mut accx = 0.0;
                            let mut accy = 0.0;
                            for q in 0..nq {
                                accx +=
                                    b.gx[base + q] * ws.ux[ei * nq + q];
                            }
                            for q in 0..nq {
                                accy +=
                                    b.gy[base + q] * ws.uy[ei * nq + q];
                            }
                            let c = 1.0 * accx + 1.0 * accy;
                            let mut cv = 0.0;
                            if conv {
                                let mut acc = 0.0;
                                for q in 0..nq {
                                    let d = bx * ws.ux[ei * nq + q]
                                        + by * ws.uy[ei * nq + q];
                                    acc += b.vmat[base + q] * d;
                                }
                                cv = 1.0 * acc;
                            }
                            want[e * nt + j] =
                                eps * c + cv - b.f_mat[e * nt + j];
                        }
                    }
                }
                for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                    if g.to_bits() != w.to_bits() {
                        return Err(format!(
                            "resid[{i}]: {g:e} != closed form {w:e}"
                        ));
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn tabulated_constants_match_scalar_path_to_roundoff() {
        // ForceVariable reroutes the same PDE through the table path;
        // values agree with the scalar path to roundoff (the operation
        // *order* differs — that is the point of the two paths)
        let p = TestProblem::constant(0.8, (0.4, -0.3), -1.2);
        let pv = crate::problems::ForceVariable::new(TestProblem {
            eps: 0.8,
            b: (0.4, -0.3),
            c: -1.2,
            var: CoeffVariability::CONST,
        });
        let mut bc =
            build_backend(2, &[2, 4, 1], NativeLoss::Forward, 12, 0, &p);
        let mut bt =
            build_backend(2, &[2, 4, 1], NativeLoss::Forward, 12, 0, &pv);
        assert!(bc.eps_scale().is_some(), "scalar fast path expected");
        assert!(bt.eps_scale().is_none(), "table path expected");
        let (sc, gc) = bc.loss_and_grad().unwrap();
        let (st, gt) = bt.loss_and_grad().unwrap();
        assert!((sc.loss - st.loss).abs() < 1e-12 * (1.0 + sc.loss.abs()),
                "loss {} vs {}", sc.loss, st.loss);
        for (a, b) in gc.iter().zip(&gt) {
            assert!((a - b).abs() < 1e-11 * (1.0 + a.abs()),
                    "grad mismatch across paths: {a} vs {b}");
        }
    }

    #[test]
    fn mlp_eval_matches_scalar_reference() {
        let mlp = Mlp::glorot(&[2, 30, 30, 30, 1], 42).unwrap();
        let mut rng = Rng::new(9);
        // more points than one eval block, odd remainder
        let pts: Vec<[f64; 2]> = (0..1037)
            .map(|_| [rng.uniform_in(-1.0, 1.0), rng.uniform_in(-1.0, 1.0)])
            .collect();
        let got = mlp.eval(&pts);
        for (p, &g) in pts.iter().zip(&got) {
            let (u, _, _) = mlp.forward_point_reference(p[0], p[1]);
            assert!((g as f64 - u).abs() < 1e-6,
                    "eval {g} vs reference {u}");
        }
    }

    #[test]
    fn from_theta_reproduces_glorot_layout() {
        for (layers, two_head) in [
            (vec![2usize, 4, 3, 1], false),
            (vec![2, 5, 1], true),
            (vec![2, 1], false),
        ] {
            let a = if two_head {
                Mlp::glorot_two_head(&layers, 7).unwrap()
            } else {
                Mlp::glorot(&layers, 7).unwrap()
            };
            let b =
                Mlp::from_theta(&layers, two_head, a.theta.clone())
                    .unwrap();
            let pts = [[0.3, 0.7], [-0.2, 0.9], [0.0, 0.0]];
            let (ua, ea) = a.eval_heads(&pts);
            let (ub, eb) = b.eval_heads(&pts);
            assert_eq!(ua, ub);
            assert_eq!(ea, eb);
            // wrong parameter count must be rejected, not mis-indexed
            let mut short = a.theta.clone();
            short.pop();
            assert!(Mlp::from_theta(&layers, two_head, short).is_err());
        }
    }

    #[test]
    fn export_load_checkpoint_roundtrip_restores_state() {
        let mut a = tiny_backend(NativeLoss::InverseConst, 6);
        for s in 1..=7 {
            a.step(s, 5e-3).unwrap();
        }
        let mut ck = a.export_checkpoint().unwrap();
        assert_eq!(ck.loss_mode, "inverse_const");
        assert_eq!(ck.adam_m.len(), ck.theta.len() + 1); // eps slot
        // serialize through the on-disk format too
        ck = Checkpoint::from_bytes(&ck.to_bytes()).unwrap();
        let mut b = tiny_backend(NativeLoss::InverseConst, 6);
        b.load_checkpoint(&ck).unwrap();
        assert_eq!(a.params_flat(), b.params_flat());
        // next step must be bit-identical on both
        let sa = a.step(8, 5e-3).unwrap();
        let sb = b.step(8, 5e-3).unwrap();
        assert_eq!(sa.loss.to_bits(), sb.loss.to_bits());
        assert_eq!(a.params_flat(), b.params_flat());
    }

    #[test]
    fn load_checkpoint_rejects_mismatched_runs() {
        let a = tiny_backend(NativeLoss::Forward, 0);
        let ck = a.export_checkpoint().unwrap();
        // different architecture
        let problem = PoissonSin::new(std::f64::consts::PI);
        let mut wider =
            build_backend(1, &[2, 6, 1], NativeLoss::Forward, 8, 0,
                          &problem);
        let err = wider.load_checkpoint(&ck).unwrap_err();
        assert!(err.to_string().contains("network"), "{err}");
        // different mesh resolution -> fingerprint mismatch
        let mut finer =
            build_backend(2, &[2, 4, 1], NativeLoss::Forward, 8, 0,
                          &problem);
        let err = finer.load_checkpoint(&ck).unwrap_err();
        assert!(err.to_string().contains("fingerprint"), "{err}");
        // different loss mode
        let mut inv = tiny_backend(NativeLoss::InverseConst, 6);
        let err = inv.load_checkpoint(&ck).unwrap_err();
        assert!(err.to_string().contains("loss mode"), "{err}");
    }
}
