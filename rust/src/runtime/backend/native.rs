//! The native pure-Rust FastVPINNs training backend.
//!
//! Implements the paper's tensor-driven train step with no XLA, no
//! artifacts and no Python:
//!
//! 1. tanh-MLP forward over all `ne*nq` quadrature points, carrying the
//!    input tangents so `(u, du/dx, du/dy)` come out of one pass
//!    (forward-mode in the two spatial directions);
//! 2. the tensor-contraction variational residual
//!    `r[e,j] = eps * sum_q (G_x[e,j,q] du/dx + G_y[e,j,q] du/dy)
//!              + sum_q V[e,j,q] (b . grad u) - F[e,j]`;
//! 3. hand-written reverse-mode backprop through the contraction and the
//!    tangent-carrying MLP (reverse-over-forward), plus the Dirichlet
//!    penalty and sensor terms;
//! 4. an Adam update (beta1 0.9, beta2 0.999, eps 1e-8).
//!
//! The element loop is parallelized over contiguous element chunks with
//! scoped threads — the same pattern as `fem::assembly` — and thread
//! partials are reduced in chunk order, so a run is deterministic for a
//! fixed thread count.

use anyhow::{anyhow, ensure, Result};

use super::{Backend, BackendOpts, DataSource, StepStats};
use crate::util::rng::Rng;

/// Which objective the native step optimizes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum NativeLoss {
    /// `-eps lap u + b . grad u = f` with fixed coefficients
    /// (`bx = by = 0` is plain Poisson).
    Forward { eps: f64, bx: f64, by: f64 },
    /// `-eps lap u = f` with trainable eps plus sensor supervision
    /// (paper SS4.7.1).
    InverseConst,
}

impl NativeLoss {
    fn kind(&self) -> &'static str {
        match self {
            NativeLoss::Forward { bx, by, .. } => {
                if *bx == 0.0 && *by == 0.0 {
                    "poisson"
                } else {
                    "cd"
                }
            }
            NativeLoss::InverseConst => "inverse_const",
        }
    }
}

/// Static configuration of a native training run.
#[derive(Debug, Clone)]
pub struct NativeConfig {
    /// MLP widths, input to output (first must be 2, last 1). The
    /// paper's standard network is `[2, 30, 30, 30, 1]`.
    pub layers: Vec<usize>,
    pub loss: NativeLoss,
    /// Dirichlet boundary sample count.
    pub nb: usize,
    /// Sensor count (inverse losses only).
    pub ns: usize,
}

impl NativeConfig {
    /// The paper's standard 30x3 forward Poisson setup.
    pub fn poisson_std() -> NativeConfig {
        NativeConfig {
            layers: vec![2, 30, 30, 30, 1],
            loss: NativeLoss::Forward { eps: 1.0, bx: 0.0, by: 0.0 },
            nb: 400,
            ns: 0,
        }
    }
}

// ---------------------------------------------------------------------
// MLP parameters
// ---------------------------------------------------------------------

/// A tanh MLP as a flat f64 parameter vector (per layer: row-major
/// `W[n_in, n_out]` then `b[n_out]`), usable standalone for
/// prediction-only workloads (e.g. the Table 1 timing run).
#[derive(Debug, Clone)]
pub struct Mlp {
    pub layers: Vec<usize>,
    pub theta: Vec<f64>,
    /// (w_offset, b_offset) per weight layer.
    offsets: Vec<(usize, usize)>,
}

impl Mlp {
    /// Glorot-uniform weights, zero biases (same distribution and RNG as
    /// the XLA path's init).
    pub fn glorot(layers: &[usize], seed: u64) -> Result<Mlp> {
        ensure!(layers.len() >= 2, "need at least input+output layer");
        ensure!(layers[0] == 2, "input width must be 2 (x, y)");
        ensure!(*layers.last().unwrap() == 1, "output width must be 1");
        let mut rng = Rng::new(seed);
        let mut theta = Vec::new();
        let mut offsets = Vec::new();
        for w in layers.windows(2) {
            let (nin, nout) = (w[0], w[1]);
            let w_off = theta.len();
            theta.extend(rng.glorot(nin, nout).iter().map(|&v| v as f64));
            let b_off = theta.len();
            theta.resize(b_off + nout, 0.0);
            offsets.push((w_off, b_off));
        }
        Ok(Mlp { layers: layers.to_vec(), theta, offsets })
    }

    pub fn n_params(&self) -> usize {
        self.theta.len()
    }

    /// Number of weight layers.
    fn n_stages(&self) -> usize {
        self.layers.len() - 1
    }

    fn max_width(&self) -> usize {
        self.layers.iter().copied().max().unwrap_or(1)
    }

    /// Value-only forward at a batch of points (prediction path).
    pub fn eval(&self, points: &[[f64; 2]]) -> Vec<f32> {
        let wmax = self.max_width();
        let mut cur = vec![0.0; wmax];
        let mut nxt = vec![0.0; wmax];
        let mut out = Vec::with_capacity(points.len());
        for p in points {
            cur[0] = p[0];
            cur[1] = p[1];
            let last = self.n_stages() - 1;
            for (l, win) in self.layers.windows(2).enumerate() {
                let (nin, nout) = (win[0], win[1]);
                let (w_off, b_off) = self.offsets[l];
                let w = &self.theta[w_off..w_off + nin * nout];
                let b = &self.theta[b_off..b_off + nout];
                for (j, nj) in nxt.iter_mut().enumerate().take(nout) {
                    let mut z = b[j];
                    for (i, &ci) in cur.iter().enumerate().take(nin) {
                        z += ci * w[i * nout + j];
                    }
                    *nj = if l < last { z.tanh() } else { z };
                }
                std::mem::swap(&mut cur, &mut nxt);
            }
            out.push(cur[0] as f32);
        }
        out
    }
}

// ---------------------------------------------------------------------
// Per-thread forward/backward workspace
// ---------------------------------------------------------------------

/// Stored forward state of one hidden layer over a batch of points,
/// indexed `[q * width + j]`.
struct LayerTape {
    a: Vec<f64>,  // tanh activations
    ax: Vec<f64>, // post-activation x tangents = s * zx
    ay: Vec<f64>,
    zx: Vec<f64>, // pre-activation x tangents
    zy: Vec<f64>,
}

struct Workspace {
    tapes: Vec<LayerTape>, // one per hidden layer
    ux: Vec<f64>,          // per-point outputs
    uy: Vec<f64>,
    u: Vec<f64>,
    // double buffers for one point's layer state
    cur: [Vec<f64>; 3], // a, ax, ay
    nxt: [Vec<f64>; 3],
    gcur: [Vec<f64>; 3], // gz, gzx, gzy
    gnxt: [Vec<f64>; 3],
    resid: Vec<f64>, // r[j] of the current element
}

impl Workspace {
    fn new(mlp: &Mlp, max_points: usize, nt: usize) -> Workspace {
        let wmax = mlp.max_width();
        let hidden_widths: Vec<usize> =
            mlp.layers[1..mlp.layers.len() - 1].to_vec();
        let tapes = hidden_widths
            .iter()
            .map(|&w| LayerTape {
                a: vec![0.0; w * max_points],
                ax: vec![0.0; w * max_points],
                ay: vec![0.0; w * max_points],
                zx: vec![0.0; w * max_points],
                zy: vec![0.0; w * max_points],
            })
            .collect();
        let buf = || [vec![0.0; wmax], vec![0.0; wmax], vec![0.0; wmax]];
        Workspace {
            tapes,
            ux: vec![0.0; max_points],
            uy: vec![0.0; max_points],
            u: vec![0.0; max_points],
            cur: buf(),
            nxt: buf(),
            gcur: buf(),
            gnxt: buf(),
            resid: vec![0.0; nt],
        }
    }
}

/// Per-thread gradient + loss accumulator.
struct Partial {
    grad: Vec<f64>,
    var_sq: f64,
    geps: f64,
}

// ---------------------------------------------------------------------
// The backend
// ---------------------------------------------------------------------

pub struct NativeBackend {
    cfg: NativeConfig,
    net: Mlp,
    /// Diffusion coefficient; trainable iff `loss == InverseConst`.
    eps: f64,
    bx: f64,
    by: f64,
    // Adam state over net params (+ eps slot when trainable)
    m: Vec<f64>,
    v: Vec<f64>,
    // Step-invariant data, owned (f64 — no f32 runtime boundary here).
    // Owning copies of gx/gy/v/quad_xy doubles peak memory during
    // construction, but lets the caller drop the AssembledDomain
    // afterwards — at paper scale keep only one of the two alive.
    ne: usize,
    nt: usize,
    nq: usize,
    gx: Vec<f64>,
    gy: Vec<f64>,
    vmat: Vec<f64>,
    f_mat: Vec<f64>,
    quad_xy: Vec<f64>,
    bd_xy: Vec<[f64; 2]>,
    bd_u: Vec<f64>,
    sensor_xy: Vec<[f64; 2]>,
    sensor_u: Vec<f64>,
    tau: f64,
    gamma: f64,
    n_threads: usize,
}

impl NativeBackend {
    pub fn new(
        cfg: &NativeConfig,
        src: &DataSource<'_>,
        opts: &BackendOpts,
    ) -> Result<NativeBackend> {
        let dom = src.domain.ok_or_else(|| anyhow!(
            "the native backend needs assembled premultiplier tensors \
             (DataSource.domain is None)"
        ))?;
        ensure!(cfg.nb >= 4, "need at least 4 boundary samples");
        let trainable_eps = cfg.loss == NativeLoss::InverseConst;
        let (eps, bx, by) = match cfg.loss {
            NativeLoss::Forward { eps, bx, by } => (eps, bx, by),
            NativeLoss::InverseConst => (opts.eps_init, 0.0, 0.0),
        };

        let net = Mlp::glorot(&cfg.layers, opts.seed)?;
        let n_opt = net.n_params() + usize::from(trainable_eps);

        let f_mat = dom.force_matrix(|x, y| src.problem.forcing(x, y));
        let bd_xy = src.mesh.sample_boundary(cfg.nb);
        let bd_u: Vec<f64> = bd_xy
            .iter()
            .map(|p| src.problem.boundary(p[0], p[1]))
            .collect();

        let (sensor_xy, sensor_u) = if trainable_eps {
            ensure!(cfg.ns > 0,
                    "inverse_const needs ns > 0 sensor points");
            let pts = src.mesh.sample_interior(cfg.ns, opts.seed + 1);
            let vals: Vec<f64> = pts
                .iter()
                .map(|p| match src.sensor_values {
                    Some(f) => Ok(f(p[0], p[1])),
                    None => src.problem.exact(p[0], p[1]).ok_or_else(|| {
                        anyhow!(
                            "problem '{}' has no exact solution; provide \
                             DataSource.sensor_values",
                            src.problem.name()
                        )
                    }),
                })
                .collect::<Result<_>>()?;
            (pts, vals)
        } else {
            (Vec::new(), Vec::new())
        };

        let n_threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(dom.ne.max(1));

        Ok(NativeBackend {
            cfg: cfg.clone(),
            net,
            eps,
            bx,
            by,
            m: vec![0.0; n_opt],
            v: vec![0.0; n_opt],
            ne: dom.ne,
            nt: dom.nt,
            nq: dom.nq,
            gx: dom.gx.clone(),
            gy: dom.gy.clone(),
            vmat: dom.v.clone(),
            f_mat,
            quad_xy: dom.quad_xy.clone(),
            bd_xy,
            bd_u,
            sensor_xy,
            sensor_u,
            tau: opts.tau,
            gamma: opts.gamma,
            n_threads,
        })
    }

    /// Trainable parameter count (network + eps slot when present).
    pub fn n_opt_params(&self) -> usize {
        self.m.len()
    }

    pub fn network(&self) -> &Mlp {
        &self.net
    }

    fn trainable_eps(&self) -> bool {
        self.cfg.loss == NativeLoss::InverseConst
    }

    /// Flat view of the optimized parameters (tests / diagnostics).
    pub fn params_flat(&self) -> Vec<f64> {
        let mut out = self.net.theta.clone();
        if self.trainable_eps() {
            out.push(self.eps);
        }
        out
    }

    pub fn set_params_flat(&mut self, theta: &[f64]) -> Result<()> {
        ensure!(theta.len() == self.n_opt_params(),
                "expected {} params, got {}", self.n_opt_params(),
                theta.len());
        let n_net = self.net.n_params();
        self.net.theta.copy_from_slice(&theta[..n_net]);
        if self.trainable_eps() {
            self.eps = theta[n_net];
        }
        Ok(())
    }

    /// Forward + tangents for one point, recording tapes at batch slot
    /// `q`; writes (u, ux, uy) into the workspace output arrays.
    fn forward_point(&self, ws: &mut Workspace, q: usize, x: f64, y: f64) {
        let net = &self.net;
        let Workspace { tapes, ux, uy, u, cur, nxt, .. } = ws;
        cur[0][0] = x;
        cur[0][1] = y;
        cur[1][0] = 1.0;
        cur[1][1] = 0.0;
        cur[2][0] = 0.0;
        cur[2][1] = 1.0;
        let last = net.n_stages() - 1;
        for (l, win) in net.layers.windows(2).enumerate() {
            let (nin, nout) = (win[0], win[1]);
            let (w_off, b_off) = net.offsets[l];
            let w = &net.theta[w_off..w_off + nin * nout];
            let b = &net.theta[b_off..b_off + nout];
            for j in 0..nout {
                let mut z = b[j];
                let mut zx = 0.0;
                let mut zy = 0.0;
                for i in 0..nin {
                    let wij = w[i * nout + j];
                    z += cur[0][i] * wij;
                    zx += cur[1][i] * wij;
                    zy += cur[2][i] * wij;
                }
                if l < last {
                    let a = z.tanh();
                    let s = 1.0 - a * a;
                    let t = &mut tapes[l];
                    t.a[q * nout + j] = a;
                    t.zx[q * nout + j] = zx;
                    t.zy[q * nout + j] = zy;
                    t.ax[q * nout + j] = s * zx;
                    t.ay[q * nout + j] = s * zy;
                    nxt[0][j] = a;
                    nxt[1][j] = s * zx;
                    nxt[2][j] = s * zy;
                } else {
                    u[q] = z;
                    ux[q] = zx;
                    uy[q] = zy;
                }
            }
            if l < last {
                std::mem::swap(cur, nxt);
            }
        }
    }

    /// Reverse pass for one point given output seeds, accumulating into
    /// `grad` (flat layout of `Mlp::theta`). `(x, y)` is the input point
    /// (needed for the first layer's weight gradients).
    #[allow(clippy::too_many_arguments)]
    fn backward_point(
        &self,
        ws: &mut Workspace,
        grad: &mut [f64],
        q: usize,
        x: f64,
        y: f64,
        gu: f64,
        gux: f64,
        guy: f64,
    ) {
        let net = &self.net;
        let Workspace { tapes, gcur, gnxt, .. } = ws;
        gcur[0][0] = gu;
        gcur[1][0] = gux;
        gcur[2][0] = guy;
        for l in (0..net.n_stages()).rev() {
            let (nin, nout) = (net.layers[l], net.layers[l + 1]);
            let (w_off, b_off) = net.offsets[l];
            for j in 0..nout {
                let (gz, gzx, gzy) = (gcur[0][j], gcur[1][j], gcur[2][j]);
                grad[b_off + j] += gz;
                for i in 0..nin {
                    // input activations/tangents of this stage
                    let (ai, axi, ayi) = if l == 0 {
                        if i == 0 {
                            (x, 1.0, 0.0)
                        } else {
                            (y, 0.0, 1.0)
                        }
                    } else {
                        let t = &tapes[l - 1];
                        (t.a[q * nin + i], t.ax[q * nin + i],
                         t.ay[q * nin + i])
                    };
                    grad[w_off + i * nout + j] +=
                        gz * ai + gzx * axi + gzy * ayi;
                }
            }
            if l == 0 {
                break;
            }
            // pull adjoints back through W then through the tanh of the
            // previous hidden layer
            let w = &net.theta[w_off..w_off + nin * nout];
            let t = &tapes[l - 1];
            for i in 0..nin {
                let mut ga = 0.0;
                let mut gax = 0.0;
                let mut gay = 0.0;
                for j in 0..nout {
                    let wij = w[i * nout + j];
                    ga += wij * gcur[0][j];
                    gax += wij * gcur[1][j];
                    gay += wij * gcur[2][j];
                }
                let a = t.a[q * nin + i];
                let s = 1.0 - a * a;
                let zx = t.zx[q * nin + i];
                let zy = t.zy[q * nin + i];
                let ds = -2.0 * a * s; // d s / d z
                gnxt[0][i] = ga * s + gax * ds * zx + gay * ds * zy;
                gnxt[1][i] = gax * s;
                gnxt[2][i] = gay * s;
            }
            std::mem::swap(gcur, gnxt);
        }
    }

    /// Full objective + flat gradient at the current parameters (public
    /// for gradient-check tests; `step` wraps this with Adam).
    pub fn loss_and_grad(&self) -> Result<(StepStats, Vec<f64>)> {
        // ---- parallel variational part over contiguous element chunks
        let per = self.ne.div_ceil(self.n_threads);
        let this: &NativeBackend = self;
        let partials: Vec<Partial> = std::thread::scope(|s| {
            let mut handles = Vec::with_capacity(self.n_threads);
            for t in 0..self.n_threads {
                let lo = t * per;
                let hi = ((t + 1) * per).min(this.ne);
                if lo >= hi {
                    break;
                }
                handles.push(s.spawn(move || this.element_chunk(lo, hi)));
            }
            handles
                .into_iter()
                .map(|h| h.join().expect("native step worker panicked"))
                .collect()
        });

        let mut grad = vec![0.0; self.n_opt_params()];
        let mut var_sq = 0.0;
        let mut geps = 0.0;
        for p in &partials {
            for (g, pg) in grad.iter_mut().zip(&p.grad) {
                *g += pg;
            }
            var_sq += p.var_sq;
            geps += p.geps;
        }
        let var_loss = var_sq / (self.ne * self.nt) as f64;

        // ---- Dirichlet penalty (serial; nb is small)
        let mut ws = Workspace::new(&self.net,
                                    self.bd_xy.len().max(1), self.nt);
        let mut bd_sq = 0.0;
        let nb = self.bd_xy.len();
        for (k, p) in self.bd_xy.iter().enumerate() {
            self.forward_point(&mut ws, k, p[0], p[1]);
        }
        {
            let net_grad = &mut grad[..self.net.n_params()];
            for (k, p) in self.bd_xy.iter().enumerate() {
                let d = ws.u[k] - self.bd_u[k];
                bd_sq += d * d;
                let gu = 2.0 * self.tau / nb as f64 * d;
                self.backward_point(&mut ws, net_grad, k, p[0], p[1],
                                    gu, 0.0, 0.0);
            }
        }
        let bd_loss = bd_sq / nb as f64;

        // ---- sensor penalty (inverse losses)
        let mut sensor_loss = 0.0;
        if !self.sensor_xy.is_empty() {
            let ns = self.sensor_xy.len();
            let mut wss = Workspace::new(&self.net, ns, self.nt);
            for (k, p) in self.sensor_xy.iter().enumerate() {
                self.forward_point(&mut wss, k, p[0], p[1]);
            }
            let net_grad = &mut grad[..self.net.n_params()];
            let mut s_sq = 0.0;
            for (k, p) in self.sensor_xy.iter().enumerate() {
                let d = wss.u[k] - self.sensor_u[k];
                s_sq += d * d;
                let gu = 2.0 * self.gamma / ns as f64 * d;
                self.backward_point(&mut wss, net_grad, k, p[0], p[1],
                                    gu, 0.0, 0.0);
            }
            sensor_loss = s_sq / ns as f64;
        }

        if self.trainable_eps() {
            let n_net = self.net.n_params();
            grad[n_net] = geps;
        }

        let loss = var_loss + self.tau * bd_loss + self.gamma * sensor_loss;
        let extra = if self.trainable_eps() {
            self.eps
        } else {
            sensor_loss
        };
        Ok((StepStats { loss, var_loss, bd_loss, extra }, grad))
    }

    /// The per-chunk worker (runs on scoped threads).
    fn element_chunk(&self, lo: usize, hi: usize) -> Partial {
        let (nt, nq) = (self.nt, self.nq);
        let cr = 2.0 / (self.ne * nt) as f64;
        let mut ws = Workspace::new(&self.net, nq, nt);
        let mut part = Partial {
            grad: vec![0.0; self.net.n_params()],
            var_sq: 0.0,
            geps: 0.0,
        };
        for e in lo..hi {
            let base_xy = 2 * e * nq;
            for q in 0..nq {
                let x = self.quad_xy[base_xy + 2 * q];
                let y = self.quad_xy[base_xy + 2 * q + 1];
                self.forward_point(&mut ws, q, x, y);
            }
            for j in 0..nt {
                let base = (e * nt + j) * nq;
                let gxr = &self.gx[base..base + nq];
                let gyr = &self.gy[base..base + nq];
                let mut c = 0.0;
                for q in 0..nq {
                    c += gxr[q] * ws.ux[q] + gyr[q] * ws.uy[q];
                }
                let mut conv = 0.0;
                if self.bx != 0.0 || self.by != 0.0 {
                    let vr = &self.vmat[base..base + nq];
                    for q in 0..nq {
                        conv += vr[q]
                            * (self.bx * ws.ux[q] + self.by * ws.uy[q]);
                    }
                }
                let r = self.eps * c + conv - self.f_mat[e * nt + j];
                ws.resid[j] = r;
                part.var_sq += r * r;
                part.geps += cr * r * c;
            }
            for q in 0..nq {
                let mut gux = 0.0;
                let mut guy = 0.0;
                for j in 0..nt {
                    let base = (e * nt + j) * nq;
                    let rj = cr * ws.resid[j];
                    gux += rj * (self.eps * self.gx[base + q]
                        + self.bx * self.vmat[base + q]);
                    guy += rj * (self.eps * self.gy[base + q]
                        + self.by * self.vmat[base + q]);
                }
                let x = self.quad_xy[base_xy + 2 * q];
                let y = self.quad_xy[base_xy + 2 * q + 1];
                self.backward_point(&mut ws, &mut part.grad, q, x, y,
                                    0.0, gux, guy);
            }
        }
        part
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn loss_kind(&self) -> &str {
        self.cfg.loss.kind()
    }

    fn step(&mut self, step: usize, lr: f64) -> Result<StepStats> {
        ensure!(step >= 1, "step is 1-based");
        let (mut stats, grad) = self.loss_and_grad()?;
        // Adam
        const B1: f64 = 0.9;
        const B2: f64 = 0.999;
        const EPS: f64 = 1e-8;
        let bc1 = 1.0 - B1.powi(step as i32);
        let bc2 = 1.0 - B2.powi(step as i32);
        let n_net = self.net.n_params();
        for (i, &g) in grad.iter().enumerate() {
            self.m[i] = B1 * self.m[i] + (1.0 - B1) * g;
            self.v[i] = B2 * self.v[i] + (1.0 - B2) * g * g;
            let update =
                lr * (self.m[i] / bc1) / ((self.v[i] / bc2).sqrt() + EPS);
            if i < n_net {
                self.net.theta[i] -= update;
            } else {
                self.eps -= update;
            }
        }
        // report the post-update eps, matching the XLA backend (which
        // reads eps back from the updated device state)
        if self.trainable_eps() {
            stats.extra = self.eps;
        }
        Ok(stats)
    }

    fn predict(&self, points: &[[f64; 2]]) -> Result<Vec<Vec<f32>>> {
        Ok(vec![self.net.eval(points)])
    }

    fn current_eps(&self) -> Option<f64> {
        if self.trainable_eps() {
            Some(self.eps)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autodiff::Dual2;
    use crate::fem::assembly;
    use crate::fem::quadrature::QuadKind;
    use crate::mesh::generators;
    use crate::problems::PoissonSin;

    fn tiny_backend(loss: NativeLoss, ns: usize) -> NativeBackend {
        let mesh = generators::unit_square(1);
        let dom = assembly::assemble(&mesh, 2, 3, QuadKind::GaussLegendre);
        let problem = PoissonSin::new(std::f64::consts::PI);
        let src = DataSource {
            mesh: &mesh,
            domain: Some(&dom),
            problem: &problem,
            sensor_values: None,
        };
        let cfg = NativeConfig {
            layers: vec![2, 4, 1],
            loss,
            nb: 8,
            ns,
        };
        NativeBackend::new(&cfg, &src, &BackendOpts::default()).unwrap()
    }

    /// Reference loss with Dual2 parameters: recomputes the exact same
    /// objective as `loss_and_grad`, but with parameter `k` as the
    /// active Dual2 variable, so `.d1` is the exact dLoss/dtheta_k.
    fn loss_dual(b: &NativeBackend, k: usize) -> Dual2 {
        let theta = b.params_flat();
        let p = |i: usize| -> Dual2 {
            if i == k {
                Dual2::var(theta[i])
            } else {
                Dual2::con(theta[i])
            }
        };
        let n_net = b.net.n_params();
        let eps_d = if b.trainable_eps() {
            p(n_net)
        } else {
            Dual2::con(b.eps)
        };
        let wmax = b.net.max_width();
        // forward with tangent-carrying Dual2 arithmetic
        let fwd = |x: f64, y: f64| -> (Dual2, Dual2, Dual2) {
            let zero = Dual2::con(0.0);
            let mut a = vec![zero; wmax];
            let mut ax = vec![zero; wmax];
            let mut ay = vec![zero; wmax];
            a[0] = Dual2::con(x);
            a[1] = Dual2::con(y);
            ax[0] = Dual2::con(1.0);
            ay[1] = Dual2::con(1.0);
            let last = b.net.n_stages() - 1;
            for (l, win) in b.net.layers.windows(2).enumerate() {
                let (nin, nout) = (win[0], win[1]);
                let (w_off, b_off) = b.net.offsets[l];
                let mut na = vec![zero; wmax];
                let mut nax = vec![zero; wmax];
                let mut nay = vec![zero; wmax];
                for j in 0..nout {
                    let mut z = p(b_off + j);
                    let mut zx = zero;
                    let mut zy = zero;
                    for i in 0..nin {
                        let w = p(w_off + i * nout + j);
                        z = z + a[i] * w;
                        zx = zx + ax[i] * w;
                        zy = zy + ay[i] * w;
                    }
                    if l < last {
                        let t = z.tanh();
                        let s = Dual2::con(1.0) - t * t;
                        na[j] = t;
                        nax[j] = s * zx;
                        nay[j] = s * zy;
                    } else {
                        na[j] = z;
                        nax[j] = zx;
                        nay[j] = zy;
                    }
                }
                a = na;
                ax = nax;
                ay = nay;
            }
            (a[0], ax[0], ay[0])
        };

        let (ne, nt, nq) = (b.ne, b.nt, b.nq);
        let mut var = Dual2::con(0.0);
        for e in 0..ne {
            let mut ux = Vec::with_capacity(nq);
            let mut uy = Vec::with_capacity(nq);
            for q in 0..nq {
                let x = b.quad_xy[2 * (e * nq + q)];
                let y = b.quad_xy[2 * (e * nq + q) + 1];
                let (_, dx, dy) = fwd(x, y);
                ux.push(dx);
                uy.push(dy);
            }
            for j in 0..nt {
                let base = (e * nt + j) * nq;
                let mut c = Dual2::con(0.0);
                let mut conv = Dual2::con(0.0);
                for q in 0..nq {
                    c = c + ux[q] * b.gx[base + q] + uy[q] * b.gy[base + q];
                    conv = conv
                        + (ux[q] * b.bx + uy[q] * b.by) * b.vmat[base + q];
                }
                let r = eps_d * c + conv - Dual2::con(b.f_mat[e * nt + j]);
                var = var + r * r;
            }
        }
        var = var * (1.0 / (ne * nt) as f64);

        let mut bd = Dual2::con(0.0);
        for (i, pt) in b.bd_xy.iter().enumerate() {
            let (u, _, _) = fwd(pt[0], pt[1]);
            let d = u - Dual2::con(b.bd_u[i]);
            bd = bd + d * d;
        }
        bd = bd * (1.0 / b.bd_xy.len() as f64);

        let mut sens = Dual2::con(0.0);
        if !b.sensor_xy.is_empty() {
            for (i, pt) in b.sensor_xy.iter().enumerate() {
                let (u, _, _) = fwd(pt[0], pt[1]);
                let d = u - Dual2::con(b.sensor_u[i]);
                sens = sens + d * d;
            }
            sens = sens * (1.0 / b.sensor_xy.len() as f64);
        }

        var + bd * b.tau + sens * b.gamma
    }

    fn check_grad(b: &NativeBackend, tol: f64) {
        let (stats, grad) = b.loss_and_grad().unwrap();
        let l_ref = loss_dual(b, 0).v;
        assert!(
            (stats.loss - l_ref).abs() <= 1e-10 * (1.0 + l_ref.abs()),
            "loss mismatch: {} vs Dual2 {}", stats.loss, l_ref
        );
        for k in 0..b.n_opt_params() {
            let want = loss_dual(b, k).d1;
            let got = grad[k];
            let denom = 1.0 + want.abs().max(got.abs());
            assert!(
                ((got - want) / denom).abs() < tol,
                "param {k}: backprop {got} vs Dual2 {want}"
            );
        }
    }

    #[test]
    fn backprop_matches_dual2_poisson() {
        let b = tiny_backend(
            NativeLoss::Forward { eps: 1.0, bx: 0.0, by: 0.0 }, 0);
        check_grad(&b, 1e-10);
    }

    #[test]
    fn backprop_matches_dual2_convection() {
        let b = tiny_backend(
            NativeLoss::Forward { eps: 0.7, bx: 0.3, by: -0.2 }, 0);
        check_grad(&b, 1e-10);
    }

    #[test]
    fn backprop_matches_dual2_inverse_eps() {
        let b = tiny_backend(NativeLoss::InverseConst, 4);
        check_grad(&b, 1e-10);
    }

    #[test]
    fn step_decreases_loss_on_tiny_problem() {
        let mut b = tiny_backend(
            NativeLoss::Forward { eps: 1.0, bx: 0.0, by: 0.0 }, 0);
        let first = b.step(1, 1e-2).unwrap();
        let mut last = first;
        for i in 2..=100 {
            last = b.step(i, 1e-2).unwrap();
        }
        assert!(last.loss < first.loss,
                "loss did not decrease: {} -> {}", first.loss, last.loss);
    }

    #[test]
    fn deterministic_given_seed() {
        let run = || {
            let mut b = tiny_backend(
                NativeLoss::Forward { eps: 1.0, bx: 0.0, by: 0.0 }, 0);
            let mut out = 0.0;
            for i in 1..=20 {
                out = b.step(i, 1e-3).unwrap().loss;
            }
            out
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn predict_shape_and_determinism() {
        let b = tiny_backend(
            NativeLoss::Forward { eps: 1.0, bx: 0.0, by: 0.0 }, 0);
        let pts = [[0.2, 0.3], [0.8, 0.9]];
        let h = b.predict(&pts).unwrap();
        assert_eq!(h.len(), 1);
        assert_eq!(h[0].len(), 2);
        assert_eq!(b.predict(&pts).unwrap()[0], h[0]);
    }

    #[test]
    fn mlp_eval_matches_forward_point() {
        let b = tiny_backend(
            NativeLoss::Forward { eps: 1.0, bx: 0.0, by: 0.0 }, 0);
        let mut ws = Workspace::new(&b.net, 1, b.nt);
        b.forward_point(&mut ws, 0, 0.37, 0.61);
        let v = b.net.eval(&[[0.37, 0.61]])[0];
        assert!((v as f64 - ws.u[0]).abs() < 1e-6);
    }
}
