//! The runtime layer: training backends plus (behind `--features xla`)
//! the PJRT engine that loads AOT artifacts (HLO text + JSON manifest)
//! produced by `python -m compile.aot` and executes them on the CPU
//! PJRT client.
//!
//! Python never runs here — this is the self-contained request path.
//! With default features the layer is pure Rust: the native backend
//! trains with no artifacts at all.

pub mod backend;
#[cfg(feature = "xla")]
pub mod engine;
pub mod manifest;
pub mod tensor;
