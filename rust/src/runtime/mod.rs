//! PJRT runtime: loads AOT artifacts (HLO text + JSON manifest) produced
//! by `python -m compile.aot` and executes them on the CPU PJRT client.
//!
//! Python never runs here — this is the self-contained request path.

pub mod engine;
pub mod manifest;
pub mod tensor;
