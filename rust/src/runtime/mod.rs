//! The runtime layer: training backends plus (behind `--features xla`)
//! the PJRT engine that loads AOT artifacts (HLO text + JSON manifest)
//! produced by `python -m compile.aot` and executes them on the CPU
//! PJRT client.
//!
//! Python never runs here — this is the self-contained request path.
//! With default features the layer is pure Rust: the native backend
//! trains with no artifacts at all.
//!
//! Besides the backends, the layer owns the serve-trained-models
//! story: [`checkpoint`] defines the versioned on-disk artifact a
//! trained backend exports (and resumes from), and [`infer`] is the
//! batched inference engine that loads such an artifact and answers
//! point-cloud queries through the blocked-GEMM forward path.
//!
//! The layer also owns the runtime's failure model: [`failpoint`] is
//! the deterministic fault-injection registry that the chaos test
//! tier arms to drive the crash-safe checkpoint generation ring
//! ([`checkpoint`]) and the coordinator's divergence-recovery loop
//! through real torn writes, injected NaNs and kernel faults.

pub mod backend;
pub mod checkpoint;
#[cfg(feature = "xla")]
pub mod engine;
pub mod failpoint;
pub mod infer;
pub mod manifest;
pub mod tensor;
