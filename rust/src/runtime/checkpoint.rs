//! Versioned on-disk checkpoints: persist a trained FastVPINNs model,
//! resume it, or serve it — the durable artifact behind
//! `repro train --checkpoint` / `--resume` and `repro infer`.
//!
//! A [`Checkpoint`] captures everything needed to (a) reproduce the
//! trained network's predictions **bit-for-bit** and (b) warm-restart
//! the optimizer so a resumed run continues the loss trajectory of the
//! uninterrupted one:
//!
//! - the MLP layer shapes and flat `f64` parameter vector (both heads
//!   of a two-head inverse-space network),
//! - the trainable scalar diffusion (`inverse_const` runs),
//! - the full Adam state (`m`, `v`, step count),
//! - the hoisted [`VariationalForm`] coefficient description (the PDE
//!   the model was trained on, as data),
//! - a [`DomainFingerprint`] of the mesh/quadrature the run used,
//! - the scalar training hyper-parameters ([`TrainHyper`]) plus the
//!   registry problem id and the CLI flags that built the setup, and
//! - an integrity checksum over the whole artifact.
//!
//! ## On-disk format (version 1)
//!
//! All integers little-endian; all floating-point payload values are
//! raw IEEE-754 `f64` bit patterns (which is what makes reloaded
//! predictions bit-identical — no text round-trip on the weights):
//!
//! ```text
//! offset        size  field
//! 0             8     magic bytes "FVPCHKPT"
//! 8             1     format version byte (= 1)
//! 9             4     u32 byte length L of the metadata blob
//! 13            L     metadata: one UTF-8 JSON object (see below)
//! 13+L          8*N   payload: N f64 values, the concatenation of the
//!                     sections listed (in order, with lengths) by the
//!                     metadata's "sections" key:
//!                       theta    network parameters, flat Mlp layout
//!                       eps      the trainable scalar diffusion (1)
//!                       adam_m   Adam first-moment state
//!                       adam_v   Adam second-moment state
//!                       form_eps weak-form diffusion (1 if constant,
//!                                ne*nq if tabulated)
//!                       form_bx  weak-form convection x  (ditto)
//!                       form_by  weak-form convection y  (ditto)
//!                       form_c   weak-form reaction      (ditto)
//! 13+L+8*N      8     u64 FNV-1a checksum of ALL preceding bytes
//! ```
//!
//! The metadata object carries the structure (problem ids, CLI flags,
//! layer widths, two-head flag, step count, hyper-parameters, domain
//! fingerprint, and the kind — constant or tabulated — of each weak-
//! form coefficient). Scalar floats in the metadata round-trip exactly
//! through Rust's shortest-representation `f64` formatting/parsing;
//! everything numerically load-bearing lives in the binary payload
//! regardless.
//!
//! **Compatibility rule:** the version byte is authoritative. A reader
//! accepts exactly the versions it knows (this build: version 1) and
//! rejects anything else with a clear error — there is no silent
//! best-effort migration. Any layout change (new section, reordered
//! fields, different hash) bumps the byte.

use std::path::{Path, PathBuf};

use anyhow::{bail, ensure, Context, Result};

use crate::runtime::backend::form::{Coeff, VariationalForm};
use crate::runtime::failpoint;
use crate::util::json::Json;

/// The artifact's leading magic bytes.
pub const MAGIC: [u8; 8] = *b"FVPCHKPT";

/// The format version this build writes — and the only one it reads
/// (see the module-level compatibility rule).
pub const FORMAT_VERSION: u8 = 1;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

fn fnv1a_update(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// FNV-1a 64-bit hash of a byte slice — the artifact's integrity
/// checksum (and the primitive behind the fingerprint/prediction
/// hashes). Standard parameters, so any FNV-1a implementation can
/// verify an artifact.
pub fn fnv1a_64(bytes: &[u8]) -> u64 {
    fnv1a_update(FNV_OFFSET, bytes)
}

/// FNV-1a over the little-endian bit patterns of an `f64` slice: equal
/// hashes mean bit-identical values. Used for the domain fingerprint's
/// quadrature hash.
pub fn hash_f64_bits(vals: &[f64]) -> u64 {
    vals.iter()
        .fold(FNV_OFFSET, |h, v| fnv1a_update(h, &v.to_le_bytes()))
}

/// FNV-1a over the little-endian bit patterns of an `f32` slice —
/// `repro train --checkpoint` and `repro infer` both print this over
/// their quadrature-point predictions, so bit-for-bit agreement is a
/// string comparison away.
pub fn hash_f32_bits(vals: &[f32]) -> u64 {
    vals.iter()
        .fold(FNV_OFFSET, |h, v| fnv1a_update(h, &v.to_le_bytes()))
}

/// Identity of the assembled domain a checkpoint was trained on. A
/// resumed run must reproduce it exactly — the quadrature hash covers
/// the bit patterns of every quadrature point, so a different mesh,
/// refinement level or quadrature order is rejected up front instead
/// of silently optimizing a different objective.
#[derive(Debug, Clone, PartialEq)]
pub struct DomainFingerprint {
    /// Element count.
    pub ne: usize,
    /// Test functions per element.
    pub nt: usize,
    /// Quadrature points per element.
    pub nq: usize,
    /// Mesh point count.
    pub n_points: usize,
    /// Mesh cell count.
    pub n_cells: usize,
    /// Mesh bounding box `[x0, y0, x1, y1]`.
    pub bbox: [f64; 4],
    /// [`hash_f64_bits`] over the assembled `quad_xy` coordinates.
    pub quad_hash: u64,
}

/// Scalar training hyper-parameters captured in the artifact — enough
/// to rebuild an identical [`BackendOpts`](super::backend::BackendOpts)
/// + sampling configuration on resume (the boundary and sensor point
/// sets are re-drawn from `seed`, so they match the original run).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TrainHyper {
    /// Dirichlet penalty (paper's tau).
    pub tau: f64,
    /// Sensor penalty (paper's gamma).
    pub gamma: f64,
    /// RNG seed (weights init + boundary/sensor sampling).
    pub seed: u64,
    /// Initial guess for the trainable scalar eps (inverse_const).
    pub eps_init: f64,
    /// Dirichlet boundary sample count.
    pub nb: usize,
    /// Sensor count (inverse losses).
    pub ns: usize,
}

/// A trained (or training) FastVPINNs model as a plain data record —
/// see the module docs for the on-disk layout. Produced by
/// [`Backend::export_checkpoint`](super::backend::Backend::export_checkpoint),
/// consumed by
/// [`NativeBackend::from_checkpoint`](super::backend::native::NativeBackend::from_checkpoint)
/// (warm restart) and
/// [`InferenceSession`](super::infer::InferenceSession) (serving).
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    /// Registry problem id (`repro train --problem <this>`); empty for
    /// manual exports that never went through the CLI.
    pub problem: String,
    /// The problem *instance* label ([`Problem::name`]) — e.g.
    /// `helmholtz_k6.283`.
    ///
    /// [`Problem::name`]: crate::problems::Problem::name
    pub problem_label: String,
    /// Native loss mode: `forward`, `inverse_const` or `inverse_space`.
    pub loss_mode: String,
    /// Derived loss family (`poisson`, `helmholtz`, `cd`, ...).
    pub loss_kind: String,
    /// The CLI flags that built the training setup (mesh size,
    /// wavenumber, quadrature orders, ...), persisted so `--resume`
    /// and `repro infer --quad` can rebuild it without re-typing.
    pub cli: Vec<(String, String)>,
    /// MLP trunk layer widths, input to output.
    pub layers: Vec<usize>,
    /// Whether an eps field head is appended to the trunk.
    pub two_head: bool,
    /// Optimizer step count at export (Adam bias correction + LR
    /// schedule position for warm restart).
    pub step: usize,
    /// Best checkpoint metric seen by the exporting run (validation
    /// rel-L2 when a validation set was attached, else total loss) —
    /// lets a resumed run continue best-model tracking instead of
    /// clobbering `<path>.best` with a worse model. `None` when no
    /// policy-driven save has happened.
    pub best_metric: Option<f64>,
    /// Flat network parameters (the `Mlp` layout, both heads).
    pub theta: Vec<f64>,
    /// Trainable scalar diffusion (meaningful on `inverse_const`).
    pub eps: f64,
    /// Adam first moments, aligned with the optimized parameter vector
    /// (`theta` plus the eps slot on `inverse_const`).
    pub adam_m: Vec<f64>,
    /// Adam second moments (same layout as `adam_m`).
    pub adam_v: Vec<f64>,
    /// The hoisted weak-form coefficients the run trained against.
    pub form: VariationalForm,
    /// Identity of the mesh/quadrature the run used.
    pub fingerprint: DomainFingerprint,
    /// Scalar training hyper-parameters.
    pub hyper: TrainHyper,
}

/// Flat parameter count of an MLP with the given trunk widths (and
/// optionally the appended eps head) — the validation rule readers
/// apply to the `theta` section.
pub fn expected_n_params(layers: &[usize], two_head: bool) -> usize {
    let mut n = 0;
    for w in layers.windows(2) {
        n += w[0] * w[1] + w[1];
    }
    if two_head && layers.len() >= 2 {
        n += layers[layers.len() - 2] + 1;
    }
    n
}

fn coeff_len(c: &Coeff) -> usize {
    match c {
        Coeff::Const(_) => 1,
        Coeff::Table(t) => t.len(),
    }
}

fn coeff_meta(c: &Coeff) -> Json {
    match c {
        Coeff::Const(_) => Json::obj(vec![("kind", Json::str("const"))]),
        Coeff::Table(t) => Json::obj(vec![
            ("kind", Json::str("table")),
            ("len", Json::num(t.len() as f64)),
        ]),
    }
}

fn push_coeff(out: &mut Vec<u8>, c: &Coeff) {
    match c {
        Coeff::Const(v) => out.extend_from_slice(&v.to_le_bytes()),
        Coeff::Table(t) => {
            for v in t {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
    }
}

/// The fixed section order of payload version 1.
const SECTION_NAMES: [&str; 8] = [
    "theta", "eps", "adam_m", "adam_v", "form_eps", "form_bx", "form_by",
    "form_c",
];

impl Checkpoint {
    /// Serialize to the version-1 artifact bytes (see module docs).
    pub fn to_bytes(&self) -> Vec<u8> {
        let sections: Vec<(&str, usize)> = vec![
            ("theta", self.theta.len()),
            ("eps", 1),
            ("adam_m", self.adam_m.len()),
            ("adam_v", self.adam_v.len()),
            ("form_eps", coeff_len(&self.form.eps)),
            ("form_bx", coeff_len(&self.form.bx)),
            ("form_by", coeff_len(&self.form.by)),
            ("form_c", coeff_len(&self.form.c)),
        ];
        let total: usize = sections.iter().map(|(_, n)| n).sum();
        let fp = &self.fingerprint;
        let meta = Json::obj(vec![
            ("format", Json::str("fastvpinns-checkpoint")),
            ("version", Json::num(FORMAT_VERSION as f64)),
            ("problem", Json::str(self.problem.as_str())),
            ("problem_label", Json::str(self.problem_label.as_str())),
            ("loss_mode", Json::str(self.loss_mode.as_str())),
            ("loss_kind", Json::str(self.loss_kind.as_str())),
            ("cli", Json::Obj(
                self.cli
                    .iter()
                    .map(|(k, v)| (k.clone(), Json::str(v.as_str())))
                    .collect(),
            )),
            ("layers", Json::Arr(
                self.layers.iter().map(|&w| Json::num(w as f64)).collect(),
            )),
            ("two_head", Json::Bool(self.two_head)),
            ("step", Json::num(self.step as f64)),
            ("best_metric", match self.best_metric {
                Some(v) => Json::num(v),
                None => Json::Null,
            }),
            ("hyper", Json::obj(vec![
                ("tau", Json::num(self.hyper.tau)),
                ("gamma", Json::num(self.hyper.gamma)),
                // hex string: a u64 seed does not fit a JSON f64
                ("seed", Json::str(format!("{:x}", self.hyper.seed))),
                ("eps_init", Json::num(self.hyper.eps_init)),
                ("nb", Json::num(self.hyper.nb as f64)),
                ("ns", Json::num(self.hyper.ns as f64)),
            ])),
            ("fingerprint", Json::obj(vec![
                ("ne", Json::num(fp.ne as f64)),
                ("nt", Json::num(fp.nt as f64)),
                ("nq", Json::num(fp.nq as f64)),
                ("n_points", Json::num(fp.n_points as f64)),
                ("n_cells", Json::num(fp.n_cells as f64)),
                ("bbox", Json::Arr(
                    fp.bbox.iter().map(|&v| Json::num(v)).collect(),
                )),
                // hex string: u64 hashes do not fit a JSON f64
                ("quad_hash",
                 Json::str(format!("{:016x}", fp.quad_hash))),
            ])),
            ("form", Json::obj(vec![
                ("eps", coeff_meta(&self.form.eps)),
                ("bx", coeff_meta(&self.form.bx)),
                ("by", coeff_meta(&self.form.by)),
                ("c", coeff_meta(&self.form.c)),
            ])),
            ("sections", Json::Arr(
                sections
                    .iter()
                    .map(|(name, n)| Json::Arr(vec![
                        Json::str(*name),
                        Json::num(*n as f64),
                    ]))
                    .collect(),
            )),
        ])
        .to_string();
        let meta_b = meta.as_bytes();
        let mut out =
            Vec::with_capacity(13 + meta_b.len() + 8 * total + 8);
        out.extend_from_slice(&MAGIC);
        out.push(FORMAT_VERSION);
        out.extend_from_slice(&(meta_b.len() as u32).to_le_bytes());
        out.extend_from_slice(meta_b);
        for v in &self.theta {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out.extend_from_slice(&self.eps.to_le_bytes());
        for v in &self.adam_m {
            out.extend_from_slice(&v.to_le_bytes());
        }
        for v in &self.adam_v {
            out.extend_from_slice(&v.to_le_bytes());
        }
        push_coeff(&mut out, &self.form.eps);
        push_coeff(&mut out, &self.form.bx);
        push_coeff(&mut out, &self.form.by);
        push_coeff(&mut out, &self.form.c);
        let ck = fnv1a_64(&out);
        out.extend_from_slice(&ck.to_le_bytes());
        out
    }

    /// Parse a version-1 artifact, validating magic, version, checksum
    /// and every structural invariant. Always an `Err` — never a panic
    /// — on malformed input.
    pub fn from_bytes(b: &[u8]) -> Result<Checkpoint> {
        ensure!(
            b.len() >= 8 + 1 + 4 + 8,
            "file too short to be a checkpoint ({} bytes)",
            b.len()
        );
        ensure!(
            b[..8] == MAGIC,
            "bad magic bytes — not a FastVPINNs checkpoint"
        );
        let version = b[8];
        ensure!(
            version == FORMAT_VERSION,
            "unsupported checkpoint version {version} (this build reads \
             only version {FORMAT_VERSION}; re-export the model with a \
             matching build)"
        );
        let body = &b[..b.len() - 8];
        let stored =
            u64::from_le_bytes(b[b.len() - 8..].try_into().unwrap());
        ensure!(
            fnv1a_64(body) == stored,
            "checkpoint is corrupted (checksum mismatch)"
        );
        let meta_len =
            u32::from_le_bytes(b[9..13].try_into().unwrap()) as usize;
        ensure!(
            13 + meta_len <= body.len(),
            "checkpoint is corrupted (metadata length {meta_len} \
             overruns the file)"
        );
        let meta = std::str::from_utf8(&b[13..13 + meta_len])
            .context("checkpoint metadata is not UTF-8")?;
        let m = Json::parse(meta)
            .context("checkpoint metadata is not valid JSON")?;

        // ---- structure -----------------------------------------------
        let layers: Vec<usize> = m
            .req("layers")?
            .as_arr()?
            .iter()
            .map(|v| v.as_usize())
            .collect::<Result<_>>()?;
        ensure!(layers.len() >= 2, "checkpoint has {} layer widths, \
                 need at least input + output", layers.len());
        let two_head = m.req("two_head")?.as_bool()?;
        let loss_mode = m.req("loss_mode")?.as_str()?.to_string();
        let cli: Vec<(String, String)> = match m.req("cli")? {
            Json::Obj(o) => o
                .iter()
                .map(|(k, v)| Ok((k.clone(), v.as_str()?.to_string())))
                .collect::<Result<_>>()?,
            other => bail!("'cli' must be an object, got {other:?}"),
        };
        let sections: Vec<(String, usize)> = m
            .req("sections")?
            .as_arr()?
            .iter()
            .map(|s| {
                let pair = s.as_arr()?;
                ensure!(pair.len() == 2, "malformed section entry");
                Ok((pair[0].as_str()?.to_string(), pair[1].as_usize()?))
            })
            .collect::<Result<_>>()?;
        ensure!(
            sections.len() == SECTION_NAMES.len()
                && sections
                    .iter()
                    .zip(SECTION_NAMES)
                    .all(|((name, _), want)| name == want),
            "unexpected payload sections {:?} (version 1 expects {:?})",
            sections.iter().map(|(n, _)| n.as_str()).collect::<Vec<_>>(),
            SECTION_NAMES
        );
        ensure!(
            sections[1].1 == 1,
            "eps section must hold exactly 1 value, got {}",
            sections[1].1
        );
        let total: usize = sections.iter().map(|(_, n)| n).sum();
        let payload = &body[13 + meta_len..];
        ensure!(
            payload.len() == 8 * total,
            "checkpoint is corrupted (payload holds {} bytes, sections \
             declare {})",
            payload.len(),
            8 * total
        );

        // ---- payload -------------------------------------------------
        let mut off = 0usize;
        let mut take = |n: usize| -> Vec<f64> {
            let vals = payload[8 * off..8 * (off + n)]
                .chunks_exact(8)
                .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
                .collect();
            off += n;
            vals
        };
        let theta = take(sections[0].1);
        let eps = take(1)[0];
        let adam_m = take(sections[2].1);
        let adam_v = take(sections[3].1);
        let form_meta = m.req("form")?;
        let mut coeff = |key: &str, len: usize| -> Result<Coeff> {
            let spec = form_meta.req(key)?;
            let vals = take(len);
            match spec.req("kind")?.as_str()? {
                "const" => {
                    ensure!(len == 1, "constant coefficient '{key}' \
                             has a {len}-value section");
                    Ok(Coeff::Const(vals[0]))
                }
                "table" => {
                    ensure!(
                        spec.req("len")?.as_usize()? == len,
                        "coefficient '{key}' table length disagrees \
                         with its section"
                    );
                    Ok(Coeff::Table(vals))
                }
                other => bail!(
                    "unknown coefficient kind '{other}' for '{key}'"
                ),
            }
        };
        let form = VariationalForm {
            eps: coeff("eps", sections[4].1)?,
            bx: coeff("bx", sections[5].1)?,
            by: coeff("by", sections[6].1)?,
            c: coeff("c", sections[7].1)?,
        };

        // ---- cross-validation ----------------------------------------
        let want = expected_n_params(&layers, two_head);
        ensure!(
            theta.len() == want,
            "theta section has {} parameters but layers {:?}{} imply \
             {want}",
            theta.len(),
            layers,
            if two_head { " + eps head" } else { "" }
        );
        let n_opt = want + usize::from(loss_mode == "inverse_const");
        ensure!(
            adam_m.len() == n_opt && adam_v.len() == n_opt,
            "Adam state has {}/{} entries for {} optimized parameters",
            adam_m.len(),
            adam_v.len(),
            n_opt
        );

        // ---- scalars -------------------------------------------------
        let hy = m.req("hyper")?;
        let hyper = TrainHyper {
            tau: hy.req("tau")?.as_f64()?,
            gamma: hy.req("gamma")?.as_f64()?,
            seed: u64::from_str_radix(hy.req("seed")?.as_str()?, 16)
                .context("hyper seed is not a hex u64")?,
            eps_init: hy.req("eps_init")?.as_f64()?,
            nb: hy.req("nb")?.as_usize()?,
            ns: hy.req("ns")?.as_usize()?,
        };
        let best_metric = match m.req("best_metric")? {
            Json::Null => None,
            v => Some(v.as_f64()?),
        };
        let fj = m.req("fingerprint")?;
        let bbox_v = fj.req("bbox")?.as_arr()?;
        ensure!(bbox_v.len() == 4, "fingerprint bbox needs 4 entries");
        let mut bbox = [0.0; 4];
        for (slot, v) in bbox.iter_mut().zip(bbox_v) {
            *slot = v.as_f64()?;
        }
        let quad_hash =
            u64::from_str_radix(fj.req("quad_hash")?.as_str()?, 16)
                .context("fingerprint quad_hash is not a hex u64")?;
        let fingerprint = DomainFingerprint {
            ne: fj.req("ne")?.as_usize()?,
            nt: fj.req("nt")?.as_usize()?,
            nq: fj.req("nq")?.as_usize()?,
            n_points: fj.req("n_points")?.as_usize()?,
            n_cells: fj.req("n_cells")?.as_usize()?,
            bbox,
            quad_hash,
        };

        Ok(Checkpoint {
            problem: m.req("problem")?.as_str()?.to_string(),
            problem_label: m.req("problem_label")?.as_str()?.to_string(),
            loss_mode,
            loss_kind: m.req("loss_kind")?.as_str()?.to_string(),
            cli,
            layers,
            two_head,
            step: m.req("step")?.as_usize()?,
            best_metric,
            theta,
            eps,
            adam_m,
            adam_v,
            form,
            fingerprint,
            hyper,
        })
    }

    /// Serialize and write the artifact to `path` atomically (see
    /// [`write_atomic`]): a reader of `path` — including a `--resume`
    /// after a crash — observes either the previous artifact or this
    /// one, never a torn mix.
    ///
    /// Failpoints (chaos tier): `checkpoint.write.truncate` writes a
    /// torn half-artifact non-atomically and *reports success* (silent
    /// corruption); `checkpoint.write.kill` writes the same torn
    /// prefix and then kills the process — the crash-mid-save the
    /// generation ring must survive.
    pub fn write(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        let bytes = self.to_bytes();
        if failpoint::fired("checkpoint.write.truncate") {
            std::fs::write(path, &bytes[..bytes.len() / 2])
                .with_context(|| format!(
                    "failpoint-torn write of {}", path.display()))?;
            return Ok(());
        }
        if failpoint::fired("checkpoint.write.kill") {
            std::fs::write(path, &bytes[..bytes.len() / 2]).ok();
            eprintln!(
                "failpoint checkpoint.write.kill: dying mid-write of {}",
                path.display()
            );
            std::process::exit(137);
        }
        let t0 = crate::telemetry::armed()
            .then(std::time::Instant::now);
        write_atomic(path, &bytes).with_context(
            || format!("write checkpoint {}", path.display()),
        )?;
        if let Some(t0) = t0 {
            crate::telemetry::emit(
                crate::telemetry::Event::CheckpointWrite {
                    step: self.step as u64,
                    path: path.display().to_string(),
                    bytes: bytes.len() as u64,
                    write_ms: t0.elapsed().as_secs_f64() * 1e3,
                },
            );
        }
        Ok(())
    }

    /// Rotate the generation ring at `path` and publish this artifact
    /// as the new primary: `<path>.g0` becomes `<path>.g1`, the
    /// current `<path>` becomes `<path>.g0`, then the new artifact is
    /// written atomically to `<path>`. A crash at *any* interruption
    /// point leaves at least one checksum-valid generation on disk for
    /// [`Checkpoint::read_salvage`] to find: the renames move complete
    /// artifacts without rewriting their bytes, and the final publish
    /// is [`Checkpoint::write`]'s temp+fsync+rename.
    pub fn write_generation(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        let g0 = generation_path(path, 0);
        let g1 = generation_path(path, 1);
        if g0.exists() {
            std::fs::rename(&g0, &g1).with_context(|| format!(
                "rotate checkpoint generation {} -> {}",
                g0.display(), g1.display()
            ))?;
        }
        if path.exists() {
            std::fs::rename(path, &g0).with_context(|| format!(
                "rotate checkpoint generation {} -> {}",
                path.display(), g0.display()
            ))?;
        }
        self.write(path)
    }

    /// Read and parse an artifact from `path`.
    ///
    /// Failpoint (chaos tier): `io.read.err` returns an injected I/O
    /// error instead of touching the file.
    pub fn read(path: impl AsRef<Path>) -> Result<Checkpoint> {
        if failpoint::fired("io.read.err") {
            bail!(
                "injected I/O error reading {} (failpoint io.read.err)",
                path.as_ref().display()
            );
        }
        let bytes = std::fs::read(path.as_ref()).with_context(|| {
            format!("read checkpoint {}", path.as_ref().display())
        })?;
        Checkpoint::from_bytes(&bytes).with_context(|| {
            format!("load checkpoint {}", path.as_ref().display())
        })
    }

    /// Salvage-on-load: try the primary artifact, then the generation
    /// ring (`<path>.g0`, `<path>.g1` — newest first), and return the
    /// first checkpoint that loads and checksum-verifies, together
    /// with the path it came from (callers warn when that is not the
    /// primary). Errs only when **no** generation is loadable, listing
    /// every attempt. This is what makes `--resume` survive a torn or
    /// half-written primary after a crash.
    pub fn read_salvage(
        path: impl AsRef<Path>,
    ) -> Result<(Checkpoint, std::path::PathBuf)> {
        let path = path.as_ref();
        let candidates = [
            path.to_path_buf(),
            generation_path(path, 0),
            generation_path(path, 1),
        ];
        let mut attempts = Vec::new();
        for cand in candidates {
            if !cand.exists() {
                attempts.push(format!("{}: not found", cand.display()));
                continue;
            }
            match Checkpoint::read(&cand) {
                Ok(ck) => return Ok((ck, cand)),
                Err(e) => {
                    attempts.push(format!("{}: {e:#}", cand.display()));
                }
            }
        }
        bail!(
            "no loadable checkpoint generation for {} — every candidate \
             failed (newest first):\n  {}",
            path.display(),
            attempts.join("\n  ")
        )
    }

    /// Content fingerprint of the artifact: FNV-1a over the exact
    /// serialized bytes. Two checkpoints fingerprint equal iff their
    /// artifacts are byte-identical (`to_bytes` is deterministic), so
    /// the serve layer can key its session cache on this and share one
    /// worker pool between registry names that point at the same
    /// model.
    pub fn artifact_fingerprint(&self) -> u64 {
        fnv1a_64(&self.to_bytes())
    }
}

/// Scan a registry directory for serveable checkpoint artifacts:
/// every `<name>.ckpt` primary, as `(name, path)` pairs sorted by
/// name. Ring generations (`.g0`/`.g1`), best-metric snapshots
/// (`.ckpt.best`) and atomic-write temp files (`.tmp.<pid>`) are
/// siblings of a primary, not models of their own, and are skipped —
/// the ring is still honored at *load* time via
/// [`Checkpoint::read_salvage`] on the primary path.
pub fn scan_registry(dir: &Path) -> Result<Vec<(String, PathBuf)>> {
    let entries = std::fs::read_dir(dir)
        .with_context(|| format!("scan registry {}", dir.display()))?;
    let mut models = Vec::new();
    for entry in entries {
        let path = entry
            .with_context(|| format!("scan registry {}", dir.display()))?
            .path();
        if !path.is_file() {
            continue;
        }
        if path.extension().and_then(|e| e.to_str()) != Some("ckpt") {
            continue;
        }
        let stem = match path.file_stem().and_then(|s| s.to_str()) {
            Some(s) if !s.is_empty() => s.to_string(),
            _ => continue,
        };
        models.push((stem, path));
    }
    models.sort();
    Ok(models)
}

/// Generations kept in the ring beside the primary artifact (`.g0` =
/// the previous primary, `.g1` = the one before it).
pub const GENERATIONS: usize = 2;

/// Path of ring generation `i`: `<path>.g<i>`.
pub fn generation_path(path: &Path, i: usize) -> std::path::PathBuf {
    let mut name = path.as_os_str().to_os_string();
    name.push(format!(".g{i}"));
    std::path::PathBuf::from(name)
}

/// Write `bytes` to `path` atomically: the data goes to a unique
/// sibling temp file (`<name>.tmp.<pid>` in the same directory, so the
/// final rename cannot cross a filesystem boundary), is flushed to
/// stable storage with `fsync`, and is then renamed over `path`. On
/// Unix the parent directory is fsynced afterwards so the rename
/// itself survives a power cut. A crash at any point leaves `path`
/// either untouched or holding the complete new artifact — never a
/// torn prefix. The temp file is removed on error.
pub fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    use std::io::Write;

    let file_name = path.file_name().with_context(|| {
        format!("path {} has no file name", path.display())
    })?;
    let mut tmp_name = file_name.to_os_string();
    tmp_name.push(format!(".tmp.{}", std::process::id()));
    let tmp = path.with_file_name(tmp_name);

    let staged = (|| -> Result<()> {
        let mut f = std::fs::File::create(&tmp).with_context(|| {
            format!("create temp file {}", tmp.display())
        })?;
        f.write_all(bytes)
            .with_context(|| format!("write {}", tmp.display()))?;
        // Data must be durable BEFORE the rename publishes it: a
        // rename of an unsynced file can survive a crash while its
        // contents do not, which is exactly the torn artifact the
        // temp-file dance exists to rule out.
        f.sync_all()
            .with_context(|| format!("fsync {}", tmp.display()))?;
        drop(f);
        std::fs::rename(&tmp, path).with_context(|| {
            format!("rename {} -> {}", tmp.display(), path.display())
        })
    })();
    if staged.is_err() {
        std::fs::remove_file(&tmp).ok();
        return staged;
    }

    // Best-effort: persist the directory entry for the rename. Not
    // all filesystems allow opening a directory for sync, so failures
    // here are ignored rather than failing an already-visible write.
    #[cfg(unix)]
    if let Some(dir) =
        path.parent().filter(|d| !d.as_os_str().is_empty())
    {
        if let Ok(d) = std::fs::File::open(dir) {
            d.sync_all().ok();
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint {
            problem: "helmholtz".into(),
            problem_label: "helmholtz_k6.283".into(),
            loss_mode: "forward".into(),
            loss_kind: "helmholtz".into(),
            cli: vec![("k-pi".into(), "2".into()),
                      ("n".into(), "2".into())],
            layers: vec![2, 3, 1],
            two_head: false,
            step: 1234,
            best_metric: Some(6.4e-3),
            theta: (0..expected_n_params(&[2, 3, 1], false))
                .map(|i| 0.1 * i as f64 - 0.37)
                .collect(),
            eps: 0.0,
            adam_m: vec![0.25; expected_n_params(&[2, 3, 1], false)],
            adam_v: vec![1e-9; expected_n_params(&[2, 3, 1], false)],
            form: VariationalForm {
                eps: Coeff::Const(1.0),
                bx: Coeff::Const(0.0),
                by: Coeff::Const(0.0),
                c: Coeff::Table(vec![-39.47, -39.47, 0.1 + 0.2]),
            },
            fingerprint: DomainFingerprint {
                ne: 4,
                nt: 25,
                nq: 100,
                n_points: 9,
                n_cells: 4,
                bbox: [0.0, 0.0, 1.0, 1.0],
                quad_hash: 0xdead_beef_0123_4567,
            },
            hyper: TrainHyper {
                tau: 10.0,
                gamma: 10.0,
                seed: 42,
                eps_init: 2.0,
                nb: 400,
                ns: 0,
            },
        }
    }

    #[test]
    fn fnv1a_standard_vectors() {
        assert_eq!(fnv1a_64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a_64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a_64(b"foobar"), 0x8594_4171_f739_67e8);
    }

    #[test]
    fn roundtrip_is_lossless() {
        let ck = sample();
        let bytes = ck.to_bytes();
        let back = Checkpoint::from_bytes(&bytes).unwrap();
        assert_eq!(back, ck);
        // and the serialization is deterministic
        assert_eq!(back.to_bytes(), bytes);
    }

    #[test]
    fn file_roundtrip() {
        let ck = sample();
        let p = std::env::temp_dir().join(format!(
            "fastvpinns_ckpt_rt_{}.ckpt",
            std::process::id()
        ));
        ck.write(&p).unwrap();
        let back = Checkpoint::read(&p).unwrap();
        std::fs::remove_file(&p).ok();
        assert_eq!(back, ck);
    }

    #[test]
    fn write_replaces_existing_file_and_leaves_no_temp() {
        let dir = std::env::temp_dir().join(format!(
            "fastvpinns_ckpt_atomic_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("model.ckpt");
        let ck = sample();
        ck.write(&p).unwrap();
        // overwrite with a different (longer) artifact: the rename
        // must fully replace the old bytes, not append or tear
        let mut ck2 = sample();
        ck2.form.c =
            Coeff::Table((0..57).map(|i| i as f64).collect());
        ck2.write(&p).unwrap();
        assert_eq!(Checkpoint::read(&p).unwrap(), ck2);
        // no .tmp droppings next to the artifact
        let leftovers: Vec<_> = std::fs::read_dir(&dir)
            .unwrap()
            .map(|e| e.unwrap().file_name())
            .filter(|n| n.to_string_lossy().contains(".tmp"))
            .collect();
        assert!(leftovers.is_empty(), "temp files left: {leftovers:?}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn write_to_missing_directory_fails_without_droppings() {
        let p = std::env::temp_dir()
            .join(format!("no_such_dir_{}", std::process::id()))
            .join("model.ckpt");
        let err = sample().write(&p).unwrap_err();
        assert!(
            format!("{err:#}").contains("write checkpoint"),
            "{err:#}"
        );
    }

    #[test]
    fn corrupted_payload_is_rejected() {
        let mut bytes = sample().to_bytes();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        let err = Checkpoint::from_bytes(&bytes).unwrap_err();
        assert!(err.to_string().contains("corrupted"), "{err}");
    }

    #[test]
    fn truncated_file_is_rejected() {
        let bytes = sample().to_bytes();
        for keep in [0, 5, 12, bytes.len() / 3, bytes.len() - 1] {
            assert!(
                Checkpoint::from_bytes(&bytes[..keep]).is_err(),
                "accepted a {keep}-byte truncation"
            );
        }
    }

    #[test]
    fn every_single_bit_flip_is_rejected() {
        // The trailing FNV-1a covers all preceding bytes and each
        // byte-absorption step h -> (h ^ b) * prime is a bijection in
        // h, so ANY body flip changes the final hash — and a flip in
        // the stored checksum itself mismatches the recomputed one.
        // That makes this property exhaustively checkable, not just
        // sampleable: every bit of the artifact, flipped one at a
        // time, must fail to load.
        let bytes = sample().to_bytes();
        for bit in 0..bytes.len() * 8 {
            let mut b = bytes.clone();
            b[bit / 8] ^= 1 << (bit % 8);
            assert!(
                Checkpoint::from_bytes(&b).is_err(),
                "accepted a flip of bit {} (byte {} of {})",
                bit,
                bit / 8,
                bytes.len()
            );
        }
    }

    #[test]
    fn random_double_bit_flips_are_rejected() {
        // Two independent flips via the home-grown proptest driver:
        // FNV-1a is not cryptographic, but colliding flips inside a
        // ~1 KB artifact are vanishingly unlikely — and a collision
        // found here would be a real finding about the format.
        use crate::util::proptest::check;
        let bytes = sample().to_bytes();
        let n_bits = bytes.len() * 8;
        check(
            0xC0FF_EE00,
            300,
            |r| (r.below(n_bits), r.below(n_bits)),
            |&(b1, b2)| {
                if b1 == b2 {
                    return true; // same bit twice = identity
                }
                let mut b = bytes.clone();
                b[b1 / 8] ^= 1 << (b1 % 8);
                b[b2 / 8] ^= 1 << (b2 % 8);
                Checkpoint::from_bytes(&b).is_err()
            },
        );
    }

    #[test]
    fn generation_ring_rotates_and_salvages() {
        let dir = std::env::temp_dir().join(format!(
            "fastvpinns_ckpt_ring_{}",
            std::process::id()
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("model.ckpt");

        let mut gens = Vec::new();
        for step in [100usize, 200, 300] {
            let mut ck = sample();
            ck.step = step;
            ck.write_generation(&p).unwrap();
            gens.push(ck);
        }
        // primary = newest, g0 = previous, g1 = oldest
        assert_eq!(Checkpoint::read(&p).unwrap().step, 300);
        assert_eq!(
            Checkpoint::read(generation_path(&p, 0)).unwrap().step,
            200
        );
        assert_eq!(
            Checkpoint::read(generation_path(&p, 1)).unwrap().step,
            100
        );

        // pristine primary: salvage returns it, from the primary path
        let (ck, from) = Checkpoint::read_salvage(&p).unwrap();
        assert_eq!((ck.step, from.as_path()), (300, p.as_path()));

        // torn primary (crash mid non-atomic write): walk back to g0
        let full = gens[2].to_bytes();
        std::fs::write(&p, &full[..full.len() / 2]).unwrap();
        let (ck, from) = Checkpoint::read_salvage(&p).unwrap();
        assert_eq!(ck.step, 200);
        assert_eq!(from, generation_path(&p, 0));

        // torn primary AND g0: walk back to g1
        std::fs::write(generation_path(&p, 0), b"garbage").unwrap();
        let (ck, from) = Checkpoint::read_salvage(&p).unwrap();
        assert_eq!(ck.step, 100);
        assert_eq!(from, generation_path(&p, 1));

        // everything torn: a single error listing every attempt
        std::fs::write(generation_path(&p, 1), b"").unwrap();
        let err = Checkpoint::read_salvage(&p).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("every candidate failed"), "{msg}");
        assert!(msg.contains(".g0") && msg.contains(".g1"), "{msg}");

        // a missing primary (killed between rotation and publish)
        // still salvages from the ring
        for step in [400usize, 500] {
            let mut ck = sample();
            ck.step = step;
            ck.write_generation(&p).unwrap();
        }
        std::fs::remove_file(&p).unwrap();
        let (ck, from) = Checkpoint::read_salvage(&p).unwrap();
        assert_eq!(ck.step, 400, "g0 holds the previous primary");
        assert_eq!(from, generation_path(&p, 0));

        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn wrong_magic_is_rejected() {
        let mut bytes = sample().to_bytes();
        bytes[0] = b'X';
        let err = Checkpoint::from_bytes(&bytes).unwrap_err();
        assert!(err.to_string().contains("not a FastVPINNs"), "{err}");
    }

    #[test]
    fn future_version_is_rejected_with_a_version_error() {
        // a well-formed future artifact: bump the byte, re-checksum
        let mut bytes = sample().to_bytes();
        bytes[8] = FORMAT_VERSION + 1;
        let n = bytes.len();
        let ck = fnv1a_64(&bytes[..n - 8]);
        bytes[n - 8..].copy_from_slice(&ck.to_le_bytes());
        let err = Checkpoint::from_bytes(&bytes).unwrap_err();
        assert!(
            err.to_string().contains("unsupported checkpoint version"),
            "{err}"
        );
    }

    #[test]
    fn theta_length_mismatch_is_rejected() {
        let mut ck = sample();
        ck.theta.push(0.0);
        let err = Checkpoint::from_bytes(&ck.to_bytes()).unwrap_err();
        assert!(err.to_string().contains("theta"), "{err}");
    }

    #[test]
    fn expected_params_counts_both_heads() {
        // [2,4,1]: (2*4+4) + (4*1+1) = 17; eps head adds 4+1
        assert_eq!(expected_n_params(&[2, 4, 1], false), 17);
        assert_eq!(expected_n_params(&[2, 4, 1], true), 22);
    }

    #[test]
    fn meta_floats_roundtrip_exactly() {
        let mut ck = sample();
        ck.hyper.tau = 0.1 + 0.2; // not representable in short decimal
        ck.fingerprint.bbox = [-1.0 / 3.0, 1e-17, 2.5e300, f64::MIN];
        let back = Checkpoint::from_bytes(&ck.to_bytes()).unwrap();
        assert_eq!(back.hyper.tau.to_bits(), ck.hyper.tau.to_bits());
        for (a, b) in back
            .fingerprint
            .bbox
            .iter()
            .zip(ck.fingerprint.bbox.iter())
        {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn large_seed_and_missing_best_metric_roundtrip() {
        let mut ck = sample();
        ck.hyper.seed = u64::MAX - 12345; // far beyond f64's 2^53
        ck.best_metric = None;
        let back = Checkpoint::from_bytes(&ck.to_bytes()).unwrap();
        assert_eq!(back.hyper.seed, ck.hyper.seed);
        assert_eq!(back.best_metric, None);
    }

    #[test]
    fn fingerprint_tracks_artifact_bytes() {
        let ck = sample();
        let reparsed = Checkpoint::from_bytes(&ck.to_bytes()).unwrap();
        assert_eq!(ck.artifact_fingerprint(),
                   reparsed.artifact_fingerprint());
        let mut other = sample();
        other.theta[0] += 1.0;
        assert_ne!(ck.artifact_fingerprint(),
                   other.artifact_fingerprint());
    }

    #[test]
    fn registry_scan_lists_primaries_only() {
        let dir = std::env::temp_dir().join(format!(
            "fastvpinns_registry_scan_{}",
            std::process::id()
        ));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        for name in [
            "beta.ckpt",
            "alpha.ckpt",
            "alpha.ckpt.g0",
            "alpha.ckpt.g1",
            "alpha.ckpt.best",
            "alpha.ckpt.tmp.123",
            "notes.txt",
        ] {
            std::fs::write(dir.join(name), b"x").unwrap();
        }
        let models = scan_registry(&dir).unwrap();
        let names: Vec<&str> =
            models.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, ["alpha", "beta"]);
        std::fs::remove_dir_all(&dir).ok();
    }
}
