//! Fig. 2: the *motivation* figure — loop-based hp-VPINNs training time
//! grows linearly with element count.
//!
//! (a) residual points vs median step time at 25 quad pts/elem;
//! (b) element count vs median step time at ~constant total quad points.

use anyhow::Result;

use super::common;
use crate::problems::PoissonSin;
use crate::runtime::engine::Engine;
use crate::util::cli::Args;
use crate::util::csv::CsvWriter;

pub fn run(args: &Args) -> Result<()> {
    let engine = Engine::new(args.str_or("artifacts", "artifacts"))?;
    let iters = args.usize_or("timing-iters", 30)?;
    let warmup = args.usize_or("warmup", 3)?;
    let dir = common::results_dir("fig02")?;
    let problem = PoissonSin::new(2.0 * std::f64::consts::PI);

    // (a) 25 quad/elem, growing element count -> growing residual points
    let mut w = CsvWriter::create(dir.join("fig02a_residual_points.csv"),
                                  &["ne", "residual_points", "median_ms"])?;
    println!("fig02a: hp-VPINNs (loop) step time vs residual points");
    for ne in [16usize, 64, 256, 400] {
        let name = common::hp_name(ne, 5, 5);
        let ms = common::median_step_ms(&engine, &name, &problem, iters,
                                        warmup)?;
        println!("  ne={ne:<5} pts={:<7} median {ms:.3} ms", ne * 25);
        w.row_f64(&[ne as f64, (ne * 25) as f64, ms])?;
    }
    w.flush()?;

    // (b) constant total quad (6400), growing element count
    let mut w = CsvWriter::create(dir.join("fig02b_elements.csv"),
                                  &["ne", "nq1d", "median_ms"])?;
    println!("fig02b: hp-VPINNs (loop) step time vs elements (6400 quad)");
    for (ne, nq) in [(1usize, 80usize), (4, 40), (16, 20), (64, 10),
                     (256, 5), (400, 4)] {
        let name = common::hp_name(ne, 5, nq);
        let ms = common::median_step_ms(&engine, &name, &problem, iters,
                                        warmup)?;
        println!("  ne={ne:<5} nq1d={nq:<3} median {ms:.3} ms");
        w.row_f64(&[ne as f64, nq as f64, ms])?;
    }
    w.flush()?;
    println!("fig02 -> {}", dir.display());
    Ok(())
}
