//! Fig. 2: the *motivation* figure — loop-based hp-VPINNs training time
//! grows linearly with element count.
//!
//! (a) residual points vs median step time at 25 quad pts/elem;
//! (b) element count vs median step time at ~constant total quad points.
//!
//! The loop-based hp-VPINN baseline only exists as an AOT artifact
//! (`--backend xla`); with the native backend this driver instead
//! records the native tensor-contraction step over the same sweeps,
//! which documents the contrast the figure motivates (near-flat vs
//! linear scaling).

use anyhow::Result;

use super::common::{self, ExpCtx};
use crate::problems::PoissonSin;
use crate::util::cli::Args;
use crate::util::csv::CsvWriter;

/// Run this experiment (see the module docs for what it
/// reproduces); results land under `results/`.
pub fn run(args: &Args) -> Result<()> {
    let ctx = ExpCtx::from_args(args)?;
    let iters = args.usize_or("timing-iters", 30)?;
    let warmup = args.usize_or("warmup", 3)?;
    let dir = common::results_dir("fig02")?;
    let problem = PoissonSin::new(2.0 * std::f64::consts::PI);

    let (tag, time_step): (&str, Box<dyn Fn(usize, usize) -> Result<f64> + '_>) =
        if ctx.is_native() {
            println!(
                "fig02 [native]: hp-VPINN loop artifacts unavailable — \
                 timing the native tensor step instead (use --backend xla \
                 for the loop baseline)"
            );
            ("native_step", Box::new(|ne, nq| {
                common::median_step_ms_fv(&ctx, ne, 5, nq, &problem,
                                          iters, warmup)
            }))
        } else {
            ("hp_loop", Box::new(|ne, nq| {
                common::median_step_ms_hp(&ctx, ne, 5, nq, &problem,
                                          iters, warmup)
            }))
        };

    // (a) 25 quad/elem, growing element count -> growing residual points
    let mut w = CsvWriter::create(
        dir.join(format!("fig02a_residual_points_{tag}.csv")),
        &["ne", "residual_points", "median_ms"],
    )?;
    println!("fig02a: {tag} step time vs residual points");
    for ne in [16usize, 64, 256, 400] {
        let ms = time_step(ne, 5)?;
        println!("  ne={ne:<5} pts={:<7} median {ms:.3} ms", ne * 25);
        w.row_f64(&[ne as f64, (ne * 25) as f64, ms])?;
    }
    w.flush()?;

    // (b) constant total quad (6400), growing element count
    let mut w = CsvWriter::create(
        dir.join(format!("fig02b_elements_{tag}.csv")),
        &["ne", "nq1d", "median_ms"],
    )?;
    println!("fig02b: {tag} step time vs elements (6400 quad)");
    for (ne, nq) in [(1usize, 80usize), (4, 40), (16, 20), (64, 10),
                     (256, 5), (400, 4)] {
        let ms = time_step(ne, nq)?;
        println!("  ne={ne:<5} nq1d={nq:<3} median {ms:.3} ms");
        w.row_f64(&[ne as f64, nq as f64, ms])?;
    }
    w.flush()?;
    println!("fig02 -> {}", dir.display());
    Ok(())
}
