//! Fig. 9 (+ App. Figs. 17/18): effect of h- and p-refinement on
//! FastVPINNs accuracy for the omega = 4*pi Poisson problem. Fully
//! backend-portable (FastVPINN runs only).

use anyhow::Result;

use super::common::{self, ExpCtx};
use crate::coordinator::trainer::TrainConfig;
use crate::problems::PoissonSin;
use crate::util::cli::Args;
use crate::util::csv::CsvWriter;

/// Run this experiment (see the module docs for what it
/// reproduces); results land under `results/`.
pub fn run(args: &Args) -> Result<()> {
    let ctx = ExpCtx::from_args(args)?;
    let iters = args.usize_or("iters", 5000)?;
    let dir = common::results_dir("fig09")?;
    let problem = PoissonSin::new(4.0 * std::f64::consts::PI);
    let cfg = TrainConfig { iters, log_every: 100.max(iters / 100),
                            ..TrainConfig::default() };

    // ---- h-refinement: 1 -> 16 -> 64 elements (nt=5, nq=20 per elem)
    println!("fig09 h-refinement (omega=4pi, backend={}):", ctx.name());
    let mut w = CsvWriter::create(
        dir.join("h_refinement.csv"),
        &["ne", "mae", "rmse", "rel_l2", "linf", "final_loss"],
    )?;
    for ne in [1usize, 16, 64] {
        let r = common::run_square(&ctx, ne, 5, 20, &problem, &cfg)?;
        println!("  ne={ne:<4} MAE {:.3e}  rel-L2 {:.3e}", r.errors.mae,
                 r.errors.rel_l2);
        w.row_f64(&[ne as f64, r.errors.mae, r.errors.rmse,
                    r.errors.rel_l2, r.errors.linf,
                    r.report.final_loss])?;
    }
    w.flush()?;

    // ---- p-refinement: 5^2 -> 20^2 test functions on one element
    println!("fig09 p-refinement (1 element, omega=4pi):");
    let mut w = CsvWriter::create(
        dir.join("p_refinement.csv"),
        &["nt1d", "mae", "rmse", "rel_l2", "linf", "final_loss"],
    )?;
    for nt in [5usize, 10, 15, 20] {
        let r = common::run_square(&ctx, 1, nt, 30, &problem, &cfg)?;
        println!("  nt={nt:<3} MAE {:.3e}  rel-L2 {:.3e}", r.errors.mae,
                 r.errors.rel_l2);
        w.row_f64(&[nt as f64, r.errors.mae, r.errors.rmse,
                    r.errors.rel_l2, r.errors.linf,
                    r.report.final_loss])?;
    }
    w.flush()?;
    println!("fig09 -> {}", dir.display());
    Ok(())
}
