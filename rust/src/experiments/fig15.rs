//! Fig. 15: inverse problem with space-dependent diffusion
//! eps(x,y) = 0.5(sin x + cos y) on a 1024-cell disk; a two-head
//! network (shared tanh trunk, separate u and eps output heads, the
//! eps head softplus'd for positivity) predicts u and the diffusion
//! field simultaneously, supervised by sensor data taken from the FEM
//! reference solution. Runs on both backends: the native backend
//! trains [`crate::runtime::backend::native::NativeLoss::InverseSpace`]
//! — the eps field enters the tensor contraction per quadrature point —
//! with no artifacts; `--backend xla` executes the AOT two-head
//! artifact instead. Reports `||eps - eps*||` against
//! [`InverseSpaceCd::eps_actual`].

use anyhow::Result;

use super::common::{self, ExpCtx};
use crate::coordinator::metrics::ErrorNorms;
use crate::coordinator::trainer::{DataSource, TrainConfig, Trainer};
use crate::fem::assembly;
use crate::fem::quadrature::QuadKind;
use crate::fem_solver;
use crate::mesh::{generators, vtk};
use crate::problems::InverseSpaceCd;
use crate::runtime::backend::native::NativeConfig;
use crate::util::cli::Args;
use crate::util::csv::CsvWriter;

/// Run this experiment (see the module docs for what it
/// reproduces); results land under `results/`.
pub fn run(args: &Args) -> Result<()> {
    let ctx = ExpCtx::from_args(args)?;
    let iters = args.usize_or("iters", 4000)?;
    let ns = args.usize_or("ns", 400)?;
    let dir = common::results_dir("fig15")?;
    let problem = InverseSpaceCd;

    let mesh = generators::disk_1024();
    println!("disk mesh: {} cells (paper: 1024)", mesh.n_cells());

    // ---- FEM reference with the true eps(x,y), driven by the same
    // Problem trait object (eps_at carries the ground-truth field)
    let fem = fem_solver::solve_problem(&mesh, &problem, 3)?;
    println!("FEM reference solved in {:.2}s ({} iters)",
             fem.solve_seconds, fem.solve_iterations);

    // ---- FastVPINNs inverse training, sensors fed by the FEM field
    let dom = assembly::assemble(&mesh, 4, 5, QuadKind::GaussLegendre);
    let sensor_fn = |x: f64, y: f64| fem.eval(x, y).unwrap_or(0.0);
    let src = DataSource { mesh: &mesh, domain: Some(&dom),
                           problem: &problem,
                           sensor_values: Some(&sensor_fn) };
    let cfg = TrainConfig {
        iters,
        lr: crate::coordinator::schedule::LrSchedule::Constant(2e-3),
        log_every: 50.max(iters / 100),
        ..TrainConfig::default()
    };
    let ncfg = NativeConfig::inverse_space_std(ns);
    let backend = ctx.make_backend(&ncfg, "fv_inverse_space_disk1024",
                                   Some("predict_inv2_16k"), &src, &cfg)?;
    let mut trainer = Trainer::new(backend, &cfg);
    println!("two-head inverse-space training [{} backend], {} sensors",
             ctx.name(), ns);
    let report = trainer.run()?;
    trainer.history.to_csv(dir.join("history.csv"))?;
    println!(
        "trained {} iters, final loss {:.3e}, median {:.2} ms/iter \
         (paper: 100k epochs < 200s)",
        report.steps, report.final_loss, report.median_step_ms
    );

    // ---- evaluate both heads at mesh nodes (one trunk pass)
    let heads = trainer.predict_heads(&mesh.points)?;
    anyhow::ensure!(heads.len() >= 2,
                    "fig15 needs a two-head (u, eps) network");
    let u_pred: Vec<f64> = heads[0].iter().map(|&v| v as f64).collect();
    let eps_pred: Vec<f64> = heads[1].iter().map(|&v| v as f64).collect();
    let eps_exact: Vec<f64> = mesh
        .points
        .iter()
        .map(|p| InverseSpaceCd::eps_actual(p[0], p[1]))
        .collect();
    let u_err = ErrorNorms::compute(&u_pred, fem.nodal())?;
    let eps_err = ErrorNorms::compute(&eps_pred, &eps_exact)?;
    println!("u:   MAE {:.3e}, rel-L2 {:.3e} (paper: O(1e-2))",
             u_err.mae, u_err.rel_l2);
    println!("eps: MAE {:.3e}, rel-L2 {:.3e} (paper: O(1e-2))",
             eps_err.mae, eps_err.rel_l2);

    // ---- fields for plotting
    let u_abs: Vec<f64> = u_pred
        .iter()
        .zip(fem.nodal())
        .map(|(p, r)| (p - r).abs())
        .collect();
    let e_abs: Vec<f64> = eps_pred
        .iter()
        .zip(&eps_exact)
        .map(|(p, r)| (p - r).abs())
        .collect();
    vtk::write_point_fields(
        &mesh,
        &[("u_fem", fem.nodal()), ("u_pred", &u_pred),
          ("u_abs_err", &u_abs), ("eps_exact", &eps_exact),
          ("eps_pred", &eps_pred), ("eps_abs_err", &e_abs)],
        dir.join("disk_inverse.vtk"),
    )?;

    let mut w = CsvWriter::create(
        dir.join("summary.csv"),
        &["iters", "final_loss", "u_mae", "u_rel_l2", "eps_mae",
          "eps_rel_l2", "median_ms_per_iter", "total_secs"],
    )?;
    w.row_f64(&[report.steps as f64, report.final_loss, u_err.mae,
                u_err.rel_l2, eps_err.mae, eps_err.rel_l2,
                report.median_step_ms, report.total_seconds])?;
    w.flush()?;
    println!("fig15 -> {}", dir.display());
    Ok(())
}
