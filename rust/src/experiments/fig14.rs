//! Fig. 14: inverse problem with constant diffusion — recover eps = 0.3
//! from an initial guess of 2.0 plus 50 sensor observations
//! (paper: converged |eps - 0.3| < 1e-5 in 8909 epochs, ~2 ms/epoch).
//! Backend-portable: the native backend carries eps as an extra
//! trainable scalar with an analytic d(loss)/d(eps).

use anyhow::Result;

use super::common::{self, ExpCtx};
use crate::coordinator::metrics::{eval_grid, ErrorNorms};
use crate::coordinator::trainer::{DataSource, TrainConfig, Trainer};
use crate::fem::assembly;
use crate::fem::quadrature::QuadKind;
use crate::mesh::generators;
use crate::problems::{InverseConstPoisson, Problem};
use crate::runtime::backend::native::{NativeConfig, NativeLoss};
use crate::util::cli::Args;
use crate::util::csv::CsvWriter;

/// Run this experiment (see the module docs for what it
/// reproduces); results land under `results/`.
pub fn run(args: &Args) -> Result<()> {
    let ctx = ExpCtx::from_args(args)?;
    let iters = args.usize_or("iters", 12_000)?;
    let tol = args.f64_or("tol", 1e-3)?;
    let dir = common::results_dir("fig14")?;
    let problem = InverseConstPoisson::new();

    // domain: (-1, 1)^2, 2x2 elements, 40x40 quad (paper SS4.7.1)
    let mesh = generators::rect_grid(2, 2, -1.0, -1.0, 1.0, 1.0);
    let dom = assembly::assemble(&mesh, 5, 40, QuadKind::GaussLegendre);
    let src = DataSource { mesh: &mesh, domain: Some(&dom),
                           problem: &problem, sensor_values: None };
    let cfg = TrainConfig {
        iters,
        lr: crate::coordinator::schedule::LrSchedule::Constant(2e-3),
        log_every: 25,
        eps_init: 2.0,
        eps_converge: Some((problem.eps_actual, tol)),
        ..TrainConfig::default()
    };
    let ncfg = NativeConfig {
        layers: common::STD_LAYERS.to_vec(),
        loss: NativeLoss::InverseConst,
        nb: 400,
        ns: 50,
    };
    let backend = ctx.make_backend(&ncfg, "fv_inverse_const_ne4_nt5_nq40",
                                   Some(common::PREDICT_STD), &src, &cfg)?;
    let mut trainer = Trainer::new(backend, &cfg);
    let report = trainer.run()?;
    trainer.history.to_csv(dir.join("eps_history.csv"))?;

    let eps = report.eps_final.unwrap_or(f64::NAN);
    println!(
        "eps: init 2.0 -> {eps:.5} (actual {}), {} epochs, {:.2} ms/epoch \
         median, total {:.1}s{}",
        problem.eps_actual, report.steps, report.median_step_ms,
        report.total_seconds,
        if report.converged_early { " [converged]" } else { "" }
    );

    // solution error on (-1,1)^2
    let grid = eval_grid(100, 100, -1.0, -1.0, 1.0, 1.0);
    let exact: Vec<f64> = grid
        .iter()
        .map(|p| problem.exact(p[0], p[1]).unwrap())
        .collect();
    let pred = trainer.predict(&grid)?;
    let errors = ErrorNorms::compute_f32(&pred, &exact)?;
    println!("solution MAE {:.3e} (paper: 6.6e-2)", errors.mae);

    let mut w = CsvWriter::create(
        dir.join("summary.csv"),
        &["eps_final", "eps_actual", "eps_abs_err", "epochs",
          "median_ms_per_epoch", "total_secs", "solution_mae",
          "converged"],
    )?;
    w.row_f64(&[eps, problem.eps_actual,
                (eps - problem.eps_actual).abs(), report.steps as f64,
                report.median_step_ms, report.total_seconds, errors.mae,
                if report.converged_early { 1.0 } else { 0.0 }])?;
    w.flush()?;
    println!("fig14 -> {}", dir.display());
    Ok(())
}
