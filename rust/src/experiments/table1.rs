//! Table 1 + Fig. 19: prediction time — classical FEM solve vs a trained
//! network's forward pass, across DOF counts. Backend-portable: the
//! native backend times `Mlp::eval`; the xla backend times the AOT
//! predict artifacts.

use anyhow::Result;

use super::common::{self, ExpCtx};
use crate::fem_solver::{self, FemProblem};
use crate::mesh::generators;
use crate::runtime::backend::native::{EvalScratch, Mlp};
use crate::util::cli::Args;
use crate::util::csv::CsvWriter;

/// Smallest predict artifact that fits `n` points in one execution.
#[cfg(feature = "xla")]
fn choose_predict(n: usize) -> &'static str {
    match n {
        0..=16384 => "predict_std_16k",
        16385..=65536 => "predict_std_65k",
        65537..=262144 => "predict_std_262k",
        _ => "predict_std_1m",
    }
}

/// One timed prediction pass over all mesh points, per backend.
enum Predictor<'a> {
    /// Network + reused eval scratch, so the timed pass pays no
    /// per-call allocation (mirrors the training hot path).
    Native(Mlp, EvalScratch),
    #[cfg(feature = "xla")]
    Xla {
        engine: &'a crate::runtime::engine::Engine,
        params: Vec<xla::Literal>,
    },
    /// Uses the `'a` lifetime when the xla variant is compiled out.
    #[cfg(not(feature = "xla"))]
    #[allow(dead_code)]
    Phantom(std::marker::PhantomData<&'a ()>),
}

impl Predictor<'_> {
    fn predict(&mut self, points: &[[f64; 2]]) -> Result<usize> {
        match self {
            Predictor::Native(mlp, scratch) => {
                Ok(mlp.eval_with(points, scratch).len())
            }
            #[cfg(feature = "xla")]
            Predictor::Xla { engine, params } => {
                let out = engine.predict(choose_predict(points.len()),
                                         params, points)?;
                Ok(out[0].len())
            }
            #[cfg(not(feature = "xla"))]
            Predictor::Phantom(_) => unreachable!(),
        }
    }
}

/// Run this experiment (see the module docs for what it
/// reproduces); results land under `results/`.
pub fn run(args: &Args) -> Result<()> {
    let ctx = ExpCtx::from_args(args)?;
    let paper = args.has("paper-scale");
    let reps = args.usize_or("reps", 5)?;
    let dir = common::results_dir("table1")?;
    let om = std::f64::consts::PI;

    // random (but fixed) network parameters: prediction cost does not
    // depend on training state
    let mut predictor = match &ctx.sel {
        common::BackendSel::Native => {
            let mlp = Mlp::glorot(common::STD_LAYERS, 7)?;
            let scratch = EvalScratch::new(&mlp);
            Predictor::Native(mlp, scratch)
        }
        #[cfg(feature = "xla")]
        common::BackendSel::Xla(engine) => {
            use crate::runtime::tensor::TensorData;
            use crate::util::rng::Rng;
            let mut rng = Rng::new(7);
            let shapes: [(usize, usize); 4] =
                [(2, 30), (30, 30), (30, 30), (30, 1)];
            let mut params = Vec::new();
            for (nin, nout) in shapes {
                params.push(
                    TensorData::new(vec![nin, nout],
                                    rng.glorot(nin, nout))?
                        .to_literal()?);
                params.push(TensorData::zeros(&[nout]).to_literal()?);
            }
            Predictor::Xla { engine, params }
        }
    };

    let grids: &[usize] = if paper {
        &[170, 340, 509, 678]
    } else {
        &[64, 128, 256, 512]
    };

    println!("Table 1: FEM solve time vs NN prediction time (backend: {})",
             ctx.name());
    println!("{:>10} {:>12} {:>12} {:>10}", "DOFs", "FEM (s)",
             "predict (s)", "ratio");
    let mut w = CsvWriter::create(
        dir.join("table1.csv"),
        &["n_dof", "fem_secs", "predict_secs", "fem_over_predict"],
    )?;
    for &n in grids {
        let mesh = generators::unit_square(n);
        let n_dof = mesh.n_points();

        // --- FEM solve (assembly + CG), the paper's "prediction" cost
        let t0 = std::time::Instant::now();
        let _sol = fem_solver::solve(
            &mesh,
            &FemProblem {
                eps: &|_, _| 1.0,
                b: None,
                c: None,
                f: &|x, y| 2.0 * om * om * (om * x).sin() * (om * y).sin(),
                g: &|_, _| 0.0,
            },
            2,
        )?;
        let fem_secs = t0.elapsed().as_secs_f64();

        // --- NN prediction at the same DOF count (median of reps)
        predictor.predict(&mesh.points[..1.min(n_dof)])?; // warm up
        let mut samples = Vec::new();
        for _ in 0..reps {
            let t0 = std::time::Instant::now();
            let _ = predictor.predict(&mesh.points)?;
            samples.push(t0.elapsed().as_secs_f64());
        }
        let pred_secs = crate::util::stats::median(&samples);

        println!("{n_dof:>10} {fem_secs:>12.4} {pred_secs:>12.5} \
                  {:>9.0}x", fem_secs / pred_secs);
        w.row_f64(&[n_dof as f64, fem_secs, pred_secs,
                    fem_secs / pred_secs])?;
    }
    w.flush()?;
    println!("table1 -> {}", dir.display());
    Ok(())
}
