//! Fig. 11: high-frequency problems. (a) MAE after a fixed budget and
//! (b) wall-clock time to reach MAE 5e-2 — FastVPINNs (with matched
//! h-refinement, 6400 total quad points) vs PINNs (6400 collocation).
//! The PINN baseline needs the xla backend.

use anyhow::Result;

use super::common::{self, ExpCtx};
use crate::coordinator::metrics::eval_grid;
use crate::coordinator::trainer::{DataSource, TrainConfig, Trainer};
use crate::mesh::generators;
use crate::problems::{PoissonSin, Problem};
use crate::runtime::backend::native::NativeConfig;
use crate::util::cli::Args;
use crate::util::csv::CsvWriter;

const MAE_TARGET: f64 = 5e-2;

struct Outcome {
    mae: f64,
    secs_to_target: Option<f64>,
    iters_run: usize,
}

fn train_until(
    trainer: &mut Trainer<'_>,
    exact: &[f64],
    grid: &[[f64; 2]],
    max_iters: usize,
    chunk: usize,
) -> Result<Outcome> {
    let t0 = std::time::Instant::now();
    let mut secs_to_target = None;
    let mut iters = 0;
    let mut mae = f64::INFINITY;
    while iters < max_iters {
        for _ in 0..chunk.min(max_iters - iters) {
            trainer.step_once()?;
            iters += 1;
        }
        let err = trainer.evaluate(grid, exact)?;
        mae = err.mae;
        if secs_to_target.is_none() && mae <= MAE_TARGET {
            secs_to_target = Some(t0.elapsed().as_secs_f64());
            break;
        }
    }
    Ok(Outcome { mae, secs_to_target, iters_run: iters })
}

/// Run this experiment (see the module docs for what it
/// reproduces); results land under `results/`.
pub fn run(args: &Args) -> Result<()> {
    let ctx = ExpCtx::from_args(args)?;
    let max_iters = args.usize_or("iters", 8000)?;
    let chunk = args.usize_or("chunk", 250)?;
    let dir = common::results_dir("fig11")?;
    let grid = eval_grid(100, 100, 0.0, 0.0, 1.0, 1.0);

    let mut w = CsvWriter::create(
        dir.join("frequency_sweep.csv"),
        &["omega_over_pi", "method", "mae", "secs_to_mae_5e-2",
          "iters_run"],
    )?;

    // (omega multiplier, fv config matched to frequency)
    for (k, ne, nq) in [(2usize, 4usize, 40usize), (4, 16, 20),
                        (8, 64, 10)] {
        let omega = k as f64 * std::f64::consts::PI;
        let problem = PoissonSin::new(omega);
        let exact: Vec<f64> = grid
            .iter()
            .map(|p| problem.exact(p[0], p[1]).unwrap())
            .collect();
        let cfg = TrainConfig { iters: 1, ..TrainConfig::default() };

        // FastVPINN with h-refinement matched to the frequency
        let (mesh, dom) = common::square_domain(ne, 5, nq);
        let src = DataSource { mesh: &mesh, domain: Some(&dom),
                               problem: &problem, sensor_values: None };
        let backend = ctx.make_backend(
            &NativeConfig::forward_std(), &common::fv_name(ne, 5, nq),
            Some(common::PREDICT_STD), &src, &cfg)?;
        let mut fv = Trainer::new(backend, &cfg);
        let fv_out = train_until(&mut fv, &exact, &grid, max_iters,
                                 chunk)?;
        println!(
            "omega={k}pi fastvpinn: MAE {:.3e} ({} iters){}",
            fv_out.mae, fv_out.iters_run,
            fv_out.secs_to_target.map(|s| format!(", target in {s:.1}s"))
                .unwrap_or_default()
        );
        w.row(&[k.to_string(), "fastvpinn".into(),
                format!("{:.6e}", fv_out.mae),
                fv_out.secs_to_target.map(|s| format!("{s:.3}"))
                    .unwrap_or_else(|| "nan".into()),
                fv_out.iters_run.to_string()])?;

        // PINN with the same residual budget (xla only)
        if ctx.is_native() {
            println!("omega={k}pi pinn:      SKIP (needs --backend xla)");
            continue;
        }
        let mesh1 = generators::unit_square(1);
        let srcp = DataSource { mesh: &mesh1, domain: None,
                                problem: &problem, sensor_values: None };
        let backend = ctx.make_xla_only("pinn_poisson_nc6400",
                                        Some(common::PREDICT_STD), &srcp,
                                        &cfg)?;
        let mut pinn = Trainer::new(backend, &cfg);
        let pinn_out = train_until(&mut pinn, &exact, &grid, max_iters,
                                   chunk)?;
        println!(
            "omega={k}pi pinn:      MAE {:.3e} ({} iters){}",
            pinn_out.mae, pinn_out.iters_run,
            pinn_out.secs_to_target.map(|s| format!(", target in {s:.1}s"))
                .unwrap_or_default()
        );
        w.row(&[k.to_string(), "pinn".into(),
                format!("{:.6e}", pinn_out.mae),
                pinn_out.secs_to_target.map(|s| format!("{s:.3}"))
                    .unwrap_or_else(|| "nan".into()),
                pinn_out.iters_run.to_string()])?;
    }
    w.flush()?;
    println!("fig11 -> {}", dir.display());
    Ok(())
}
