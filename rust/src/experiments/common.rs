//! Shared experiment plumbing: CLI backend selection, standard training
//! runs over square grids, result directories, and timing measurement at
//! the paper's protocol.
//!
//! Every experiment accepts `--backend native|xla` (default: native).
//! The native backend reproduces accuracy/convergence results with no
//! artifacts — including the two-head inverse-space network (fig15),
//! which trains natively via `NativeLoss::InverseSpace`; baselines
//! that only exist as AOT artifacts (loop-based hp-VPINNs, collocation
//! PINNs) need `--features xla` plus `make artifacts` and are skipped
//! with a notice otherwise.

use std::path::PathBuf;

use anyhow::{bail, Result};

use crate::coordinator::metrics::{eval_grid, ErrorNorms};
use crate::coordinator::trainer::{DataSource, TrainConfig, Trainer};
use crate::fem::assembly::{self, AssembledDomain};
use crate::fem::quadrature::QuadKind;
use crate::mesh::{generators, QuadMesh};
use crate::problems::Problem;
use crate::runtime::backend::native::{NativeBackend, NativeConfig};
use crate::runtime::backend::{Backend, BackendOpts};
use crate::util::cli::Args;

/// results/<id>/ directory (created).
pub fn results_dir(id: &str) -> Result<PathBuf> {
    let dir = PathBuf::from("results").join(id);
    std::fs::create_dir_all(&dir)?;
    Ok(dir)
}

/// The paper's standard 30x3 network.
pub const STD_LAYERS: &[usize] = &[2, 30, 30, 30, 1];

/// The default predict artifact for the standard architecture (XLA).
pub const PREDICT_STD: &str = "predict_std_16k";

/// FastVPINN artifact name for a unit-square Poisson config.
pub fn fv_name(ne: usize, nt1d: usize, nq1d: usize) -> String {
    format!("fv_poisson_ne{ne}_nt{nt1d}_nq{nq1d}")
}

/// Loop-based hp-VPINN baseline artifact name (XLA).
pub fn hp_name(ne: usize, nt1d: usize, nq1d: usize) -> String {
    format!("hp_poisson_ne{ne}_nt{nt1d}_nq{nq1d}")
}

/// Which runtime executes the train step.
pub enum BackendSel {
    /// The pure-Rust native backend.
    Native,
    /// The AOT/PJRT artifact executor.
    #[cfg(feature = "xla")]
    Xla(crate::runtime::engine::Engine),
}

/// Per-experiment context: backend selection + shared knobs.
pub struct ExpCtx {
    /// Which runtime executes the train steps.
    pub sel: BackendSel,
}

impl ExpCtx {
    /// Resolve `--backend` (and, for XLA, `--artifacts`) into a
    /// context.
    pub fn from_args(args: &Args) -> Result<ExpCtx> {
        let name = args.str_or("backend", "native");
        crate::runtime::backend::check_backend_name(&name)?;
        let sel = match name.as_str() {
            "native" => BackendSel::Native,
            #[cfg(feature = "xla")]
            "xla" => BackendSel::Xla(crate::runtime::engine::Engine::new(
                args.str_or("artifacts", "artifacts"),
            )?),
            _ => unreachable!("check_backend_name"),
        };
        Ok(ExpCtx { sel })
    }

    /// Whether the native backend is selected.
    pub fn is_native(&self) -> bool {
        matches!(self.sel, BackendSel::Native)
    }

    /// The selected backend's id ("native", "xla").
    pub fn name(&self) -> &'static str {
        match self.sel {
            BackendSel::Native => "native",
            #[cfg(feature = "xla")]
            BackendSel::Xla(_) => "xla",
        }
    }

    /// Build a FastVPINN train backend. `native_cfg` drives the native
    /// path; `artifact`/`predict` name the AOT executables for XLA.
    pub fn make_backend<'s>(
        &'s self,
        native_cfg: &NativeConfig,
        artifact: &str,
        predict: Option<&str>,
        src: &DataSource<'_>,
        cfg: &TrainConfig,
    ) -> Result<Box<dyn Backend + 's>> {
        let opts = BackendOpts::from(cfg);
        match &self.sel {
            BackendSel::Native => {
                let _ = (artifact, predict); // XLA-path names
                Ok(Box::new(NativeBackend::new(native_cfg, src, &opts)?))
            }
            #[cfg(feature = "xla")]
            BackendSel::Xla(engine) => {
                Ok(Box::new(crate::runtime::backend::xla::XlaBackend::new(
                    engine, artifact, predict, src, &opts)?))
            }
        }
    }

    /// Build an XLA-only baseline backend (loop hp-VPINNs / collocation
    /// PINNs); errors on the native backend.
    pub fn make_xla_only<'s>(
        &'s self,
        artifact: &str,
        predict: Option<&str>,
        src: &DataSource<'_>,
        cfg: &TrainConfig,
    ) -> Result<Box<dyn Backend + 's>> {
        match &self.sel {
            BackendSel::Native => {
                let _ = (predict, src, cfg);
                bail!(
                    "baseline artifact '{artifact}' only exists on the \
                     xla backend (rebuild with --features xla and run \
                     `make artifacts`)"
                )
            }
            #[cfg(feature = "xla")]
            BackendSel::Xla(engine) => {
                let opts = BackendOpts::from(cfg);
                Ok(Box::new(crate::runtime::backend::xla::XlaBackend::new(
                    engine, artifact, predict, src, &opts)?))
            }
        }
    }
}

/// Build the unit-square mesh + assembled tensors for an artifact shape.
/// `ne` must be a perfect square (paper uses k x k grids).
pub fn square_domain(ne: usize, nt1d: usize, nq1d: usize)
    -> (QuadMesh, AssembledDomain) {
    let k = (ne as f64).sqrt().round() as usize;
    assert_eq!(k * k, ne, "ne={ne} is not a k x k grid");
    let mesh = generators::unit_square(k);
    let dom = assembly::assemble(&mesh, nt1d, nq1d, QuadKind::GaussLegendre);
    (mesh, dom)
}

/// Train a unit-square FastVPINN config on `problem`; returns (trainer
/// report, error norms on the paper's 100x100 grid, history).
pub struct SquareRun {
    /// Trainer summary.
    pub report: crate::coordinator::trainer::TrainReport,
    /// Error norms on the paper's 100x100 grid.
    pub errors: ErrorNorms,
    /// Per-step log.
    pub history: crate::coordinator::history::TrainHistory,
}

/// Train the standard FastVPINN config on a `ne`-element unit-square
/// grid and evaluate it against the problem's exact solution.
pub fn run_square(
    ctx: &ExpCtx,
    ne: usize,
    nt1d: usize,
    nq1d: usize,
    problem: &dyn Problem,
    cfg: &TrainConfig,
) -> Result<SquareRun> {
    let (mesh, dom) = square_domain(ne, nt1d, nq1d);
    let src = DataSource {
        mesh: &mesh,
        domain: Some(&dom),
        problem,
        sensor_values: None,
    };
    let ncfg = NativeConfig::forward_std();
    let backend = ctx.make_backend(&ncfg, &fv_name(ne, nt1d, nq1d),
                                   Some(PREDICT_STD), &src, cfg)?;
    let mut trainer = Trainer::new(backend, cfg);
    let report = trainer.run()?;
    let grid = eval_grid(100, 100, 0.0, 0.0, 1.0, 1.0);
    let exact: Vec<f64> = grid
        .iter()
        .map(|p| problem.exact(p[0], p[1]).unwrap_or(0.0))
        .collect();
    let errors = trainer.evaluate(&grid, &exact)?;
    Ok(SquareRun { report, errors, history: trainer.history.clone() })
}

/// Per-step wall-clock samples (ms) over `iters` steps after `warmup`
/// steps — the paper's median-time-per-epoch protocol — for any
/// backend. Feed the result to [`crate::util::stats::Summary`] for
/// median/p90 (the bench harness and `repro bench` do).
pub fn backend_step_samples_ms(
    backend: &mut dyn Backend,
    iters: usize,
    warmup: usize,
) -> Result<Vec<f64>> {
    for i in 0..warmup {
        backend.step(i + 1, 1e-3)?;
    }
    let mut samples = Vec::with_capacity(iters);
    for i in 0..iters {
        let t0 = std::time::Instant::now();
        backend.step(warmup + i + 1, 1e-3)?;
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    Ok(samples)
}

/// Median time per training step — the paper's Fig. 2/10/16 protocol.
pub fn median_backend_step_ms(
    backend: &mut dyn Backend,
    iters: usize,
    warmup: usize,
) -> Result<f64> {
    let samples = backend_step_samples_ms(backend, iters, warmup)?;
    Ok(crate::util::stats::median(&samples))
}

/// One measured case of the native step-time sweep. Shared by
/// `repro bench` (JSON record) and `benches/native_step_hotpath`
/// (console sweep) so the two harnesses cannot drift apart on the
/// per-case protocol; grid lists and iteration counts stay per-caller.
pub struct StepBenchCase {
    /// Loss family being timed ("poisson" | "cd" | "helmholtz" |
    /// "inverse_space").
    pub loss: &'static str,
    /// Which PDE drives the step ("poisson_sin" | "poisson_tab" |
    /// "helmholtz" | "cd_var" | "inverse_space_sin") — `poisson_tab`
    /// is the same constant-coefficient Poisson problem forced through
    /// the generalized per-point eps table path, the hoisting
    /// regression probe.
    pub pde: &'static str,
    /// Element count (k x k unit-square grid).
    pub ne: usize,
    /// Total quadrature points per step (`ne * nq`).
    pub n_quad: usize,
    /// Trainable parameter count.
    pub dof: usize,
    /// Effective persistent-pool workers the case ran with (requested
    /// count clamped to `ne`) — the thread-scaling sweep varies this.
    pub workers: usize,
    /// GEMM/epilogue kernel the case ran on
    /// ([`crate::linalg::simd::kernel_name`] at measurement time).
    pub kernel: &'static str,
    /// Per-step wall-clock (ms) order statistics.
    pub summary: crate::util::stats::Summary,
}

/// Time the native train step on a `k x k` unit-square Poisson grid
/// with the paper's standard 30x3 net: `iters` timed steps after
/// `warmup` discarded ones.
pub fn native_step_case(
    k: usize,
    nt1d: usize,
    nq1d: usize,
    iters: usize,
    warmup: usize,
) -> Result<StepBenchCase> {
    native_forward_step_case("poisson_sin", k, nt1d, nq1d, iters, warmup)
}

/// [`native_step_case`] pinned to an explicit persistent-pool worker
/// count — the thread-scaling sweep rows of `repro bench` (workers
/// 1/2/max at the largest grid). Losses are bit-identical across
/// worker counts by construction; only wall-clock moves.
pub fn native_step_case_workers(
    k: usize,
    nt1d: usize,
    nq1d: usize,
    iters: usize,
    warmup: usize,
    workers: usize,
) -> Result<StepBenchCase> {
    let problem =
        crate::problems::PoissonSin::new(2.0 * std::f64::consts::PI);
    let cfg = NativeConfig::forward_std();
    native_step_case_cfg(k, nt1d, nq1d, iters, warmup, &cfg, &problem,
                         "poisson", "poisson_sin", Some(workers))
}

/// Time the native forward step for one of the registered PDE cases on
/// a `k x k` unit-square grid: `poisson_sin` (scalar fast path),
/// `poisson_tab` (same PDE through the eps table path), `helmholtz`
/// (reaction term) or `cd_var` (hoisted convection tables).
pub fn native_forward_step_case(
    pde: &'static str,
    k: usize,
    nt1d: usize,
    nq1d: usize,
    iters: usize,
    warmup: usize,
) -> Result<StepBenchCase> {
    let (problem, loss): (Box<dyn Problem>, &'static str) = match pde {
        "poisson_sin" => (
            Box::new(crate::problems::PoissonSin::new(
                2.0 * std::f64::consts::PI)),
            "poisson",
        ),
        // the same constant-eps Poisson problem rerouted onto the
        // per-point eps table path: if the coefficient tables were
        // ever re-evaluated on the hot path instead of hoisted, this
        // case would blow past the poisson case's step time
        "poisson_tab" => (
            Box::new(crate::problems::ForceVariable::with(
                crate::problems::PoissonSin::new(
                    2.0 * std::f64::consts::PI),
                crate::problems::CoeffVariability {
                    eps: true,
                    b: false,
                    c: false,
                },
            )),
            "poisson",
        ),
        "helmholtz" => (
            Box::new(crate::problems::Helmholtz2D::new(
                2.0 * std::f64::consts::PI)),
            "helmholtz",
        ),
        "cd_var" => (
            Box::new(crate::problems::VariableConvectionCd::new()),
            "cd",
        ),
        other => bail!("unknown bench pde '{other}'"),
    };
    let cfg = NativeConfig::forward_std();
    native_step_case_cfg(k, nt1d, nq1d, iters, warmup, &cfg,
                         problem.as_ref(), loss, pde, None)
}

/// [`native_step_case`] with the trainer's telemetry emission replayed
/// on every timed step: when the recorder is armed each sample covers
/// the backend's per-phase clock plus one
/// [`StepStats`](crate::telemetry::Event::StepStats) emit — exactly
/// the per-step work `--metrics-out` adds to a training run. Disarmed,
/// the extra work collapses to one relaxed atomic load per step. The
/// bench harness times both and gates their ratio (the zero-overhead
/// guard).
pub fn native_step_case_telemetry(
    k: usize,
    nt1d: usize,
    nq1d: usize,
    iters: usize,
    warmup: usize,
    pde: &'static str,
) -> Result<StepBenchCase> {
    let ne = k * k;
    let mesh = generators::unit_square(k.max(1));
    let dom = assembly::assemble(&mesh, nt1d, nq1d,
                                 QuadKind::GaussLegendre);
    let problem =
        crate::problems::PoissonSin::new(2.0 * std::f64::consts::PI);
    let src = DataSource {
        mesh: &mesh,
        domain: Some(&dom),
        problem: &problem,
        sensor_values: None,
    };
    let cfg = NativeConfig::forward_std();
    let mut b = NativeBackend::new(&cfg, &src, &BackendOpts::default())?;
    let dof = b.n_opt_params();
    let workers = b.n_threads();
    for i in 0..warmup {
        b.step(i + 1, 1e-3)?;
    }
    let mut samples = Vec::with_capacity(iters);
    for i in 0..iters {
        let step = warmup + i + 1;
        let t0 = std::time::Instant::now();
        // mirror of the trainer's hot path: armedness checked once,
        // the emit (and the phase-slot take) happen inside the timed
        // window so the sample prices the full recording cost
        let t_ev =
            crate::telemetry::armed().then(std::time::Instant::now);
        let stats = b.step(step, 1e-3)?;
        if let Some(te) = t_ev {
            crate::telemetry::emit(crate::telemetry::Event::StepStats {
                step: step as u64,
                wall_ms: te.elapsed().as_secs_f64() * 1e3,
                phases_ms: crate::telemetry::take_phase_ms(),
                loss: stats.loss,
                grad_norm: stats.grad_norm,
                lr: 1e-3,
            });
        }
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    Ok(StepBenchCase {
        loss: "telemetry",
        pde,
        ne,
        n_quad: ne * dom.nq,
        dof,
        workers,
        kernel: crate::linalg::simd::kernel_name(),
        summary: crate::util::stats::Summary::from(&samples),
    })
}

/// Time the native two-head InverseSpace train step on a `k x k` grid
/// (manufactured eps-field problem, `ns` = 100 sensors): the tracked
/// `inverse_space` case of `repro bench` — the eps head's extra cost on
/// the same blocked tensor path.
pub fn native_inverse_space_step_case(
    k: usize,
    nt1d: usize,
    nq1d: usize,
    iters: usize,
    warmup: usize,
) -> Result<StepBenchCase> {
    let cfg = NativeConfig::inverse_space_std(100);
    let problem = crate::problems::InverseSpaceSin;
    native_step_case_cfg(k, nt1d, nq1d, iters, warmup, &cfg, &problem,
                         "inverse_space", "inverse_space_sin", None)
}

/// One measured case of the inference-throughput sweep: repeated full
/// passes over a fixed query cloud, evaluated in batches of `batch`
/// through the blocked-GEMM prediction path (what an
/// [`InferenceSession`](crate::runtime::infer::InferenceSession)
/// serves per request).
pub struct InferBenchCase {
    /// Points per forward call (the serving batch size).
    pub batch: usize,
    /// Query-cloud size (points evaluated per timed pass).
    pub n_points: usize,
    /// GEMM/epilogue kernel the case ran on.
    pub kernel: &'static str,
    /// Serving precision ("f64" bit-identical path, "f32"
    /// mixed-precision path).
    pub precision: &'static str,
    /// Wall-clock per full pass (ms) order statistics.
    pub summary: crate::util::stats::Summary,
    /// `n_points` / median pass time — the headline serving metric.
    pub points_per_sec: f64,
}

/// Time batched inference with the paper's standard 30x3 network:
/// `iters` timed passes (after `warmup` discarded ones) over an
/// `n_points` uniform query cloud, evaluated `batch` points at a time
/// with a reused scratch — the `repro bench` `"infer"` cases
/// (points/sec at batch sizes 1, 256, 4096, at both serving
/// precisions).
pub fn native_infer_case(
    batch: usize,
    n_points: usize,
    iters: usize,
    warmup: usize,
    precision: crate::runtime::infer::Precision,
) -> Result<InferBenchCase> {
    use crate::runtime::backend::native::{EvalScratch, Mlp};
    use crate::runtime::infer::{F32Evaluator, Precision};
    let net = Mlp::glorot(STD_LAYERS, 42)?;
    let mut scratch = EvalScratch::new(&net);
    let mut f32ev = match precision {
        Precision::F32 => Some(F32Evaluator::from_mlp(&net)),
        Precision::F64 => None,
    };
    let side = (n_points as f64).sqrt().ceil() as usize;
    let mut cloud = eval_grid(side, side, 0.0, 0.0, 1.0, 1.0);
    cloud.truncate(n_points);
    let batch = batch.max(1);
    let mut pass = || {
        for chunk in cloud.chunks(batch) {
            match f32ev.as_mut() {
                Some(ev) => {
                    std::hint::black_box(ev.eval_heads(chunk));
                }
                None => {
                    std::hint::black_box(
                        net.eval_with(chunk, &mut scratch));
                }
            }
        }
    };
    for _ in 0..warmup {
        pass();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t0 = std::time::Instant::now();
        pass();
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    let summary = crate::util::stats::Summary::from(&samples);
    Ok(InferBenchCase {
        batch,
        n_points: cloud.len(),
        kernel: crate::linalg::simd::kernel_name(),
        precision: match precision {
            Precision::F64 => "f64",
            Precision::F32 => "f32",
        },
        points_per_sec: cloud.len() as f64
            / (summary.median * 1e-3).max(1e-9),
        summary,
    })
}

/// Run `steps` native training steps on a small Poisson grid and
/// return the final loss — the numeric half of the bench harness's
/// simd-vs-scalar parity guard (the two kernels are bit-identical, so
/// any drift here means a broken kernel, not FP noise).
pub fn native_probe_loss(
    k: usize,
    nt1d: usize,
    nq1d: usize,
    steps: usize,
) -> Result<f64> {
    native_probe_loss_workers(k, nt1d, nq1d, steps, None)
}

/// [`native_probe_loss`] pinned to an explicit worker count — the
/// bench harness's worker-count determinism guard compares the
/// returned losses bit-for-bit across counts (the shard plan and the
/// fixed-order tree reduce make them identical by construction).
pub fn native_probe_loss_workers(
    k: usize,
    nt1d: usize,
    nq1d: usize,
    steps: usize,
    workers: Option<usize>,
) -> Result<f64> {
    let mesh = generators::unit_square(k.max(1));
    let dom = assembly::assemble(&mesh, nt1d, nq1d,
                                 QuadKind::GaussLegendre);
    let problem =
        crate::problems::PoissonSin::new(2.0 * std::f64::consts::PI);
    let src = DataSource {
        mesh: &mesh,
        domain: Some(&dom),
        problem: &problem,
        sensor_values: None,
    };
    let cfg = NativeConfig::forward_std();
    let opts = BackendOpts { workers, ..BackendOpts::default() };
    let mut b = NativeBackend::new(&cfg, &src, &opts)?;
    let mut loss = f64::NAN;
    for i in 0..steps.max(1) {
        loss = b.step(i + 1, 1e-3)?.loss;
    }
    Ok(loss)
}

#[allow(clippy::too_many_arguments)]
fn native_step_case_cfg(
    k: usize,
    nt1d: usize,
    nq1d: usize,
    iters: usize,
    warmup: usize,
    cfg: &NativeConfig,
    problem: &dyn Problem,
    loss: &'static str,
    pde: &'static str,
    workers: Option<usize>,
) -> Result<StepBenchCase> {
    let ne = k * k;
    let mesh = generators::unit_square(k.max(1));
    let dom = assembly::assemble(&mesh, nt1d, nq1d,
                                 QuadKind::GaussLegendre);
    let src = DataSource {
        mesh: &mesh,
        domain: Some(&dom),
        problem,
        sensor_values: None,
    };
    let opts = BackendOpts { workers, ..BackendOpts::default() };
    let mut b = NativeBackend::new(cfg, &src, &opts)?;
    let dof = b.n_opt_params();
    let workers = b.n_threads();
    let samples = backend_step_samples_ms(&mut b, iters, warmup)?;
    Ok(StepBenchCase {
        loss,
        pde,
        ne,
        n_quad: ne * dom.nq,
        dof,
        workers,
        kernel: crate::linalg::simd::kernel_name(),
        summary: crate::util::stats::Summary::from(&samples),
    })
}

/// FastVPINN step timing for a unit-square config on either backend.
pub fn median_step_ms_fv(
    ctx: &ExpCtx,
    ne: usize,
    nt1d: usize,
    nq1d: usize,
    problem: &dyn Problem,
    iters: usize,
    warmup: usize,
) -> Result<f64> {
    let (mesh, dom) = square_domain(ne, nt1d, nq1d);
    let src = DataSource {
        mesh: &mesh,
        domain: Some(&dom),
        problem,
        sensor_values: None,
    };
    let cfg = TrainConfig::default();
    let ncfg = NativeConfig::forward_std();
    let mut backend = ctx.make_backend(&ncfg, &fv_name(ne, nt1d, nq1d),
                                       None, &src, &cfg)?;
    median_backend_step_ms(backend.as_mut(), iters, warmup)
}

/// Loop-based hp-VPINN baseline step timing (XLA artifacts only).
pub fn median_step_ms_hp(
    ctx: &ExpCtx,
    ne: usize,
    nt1d: usize,
    nq1d: usize,
    problem: &dyn Problem,
    iters: usize,
    warmup: usize,
) -> Result<f64> {
    let (mesh, dom) = square_domain(ne, nt1d, nq1d);
    let src = DataSource {
        mesh: &mesh,
        domain: Some(&dom),
        problem,
        sensor_values: None,
    };
    let cfg = TrainConfig::default();
    let mut backend = ctx.make_xla_only(&hp_name(ne, nt1d, nq1d), None,
                                        &src, &cfg)?;
    median_backend_step_ms(backend.as_mut(), iters, warmup)
}

/// Collocation PINN baseline step timing (XLA artifacts only).
pub fn median_step_ms_pinn(
    ctx: &ExpCtx,
    artifact: &str,
    problem: &dyn Problem,
    iters: usize,
    warmup: usize,
) -> Result<f64> {
    let mesh = generators::unit_square(1);
    let src = DataSource {
        mesh: &mesh,
        domain: None,
        problem,
        sensor_values: None,
    };
    let cfg = TrainConfig::default();
    let mut backend = ctx.make_xla_only(artifact, None, &src, &cfg)?;
    median_backend_step_ms(backend.as_mut(), iters, warmup)
}
