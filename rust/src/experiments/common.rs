//! Shared experiment plumbing: standard training runs over square grids,
//! result directories, timing measurement at the paper's protocol.

use std::path::PathBuf;

use anyhow::Result;

use crate::coordinator::metrics::{eval_grid, ErrorNorms};
use crate::coordinator::trainer::{DataSource, TrainConfig, Trainer};
use crate::fem::assembly::{self, AssembledDomain};
use crate::fem::quadrature::QuadKind;
use crate::mesh::{generators, QuadMesh};
use crate::problems::Problem;
use crate::runtime::engine::Engine;

/// results/<id>/ directory (created).
pub fn results_dir(id: &str) -> Result<PathBuf> {
    let dir = PathBuf::from("results").join(id);
    std::fs::create_dir_all(&dir)?;
    Ok(dir)
}

/// The default predict artifact for the standard 30x3 architecture.
pub const PREDICT_STD: &str = "predict_std_16k";

/// FastVPINN artifact name for a unit-square Poisson config.
pub fn fv_name(ne: usize, nt1d: usize, nq1d: usize) -> String {
    format!("fv_poisson_ne{ne}_nt{nt1d}_nq{nq1d}")
}

pub fn hp_name(ne: usize, nt1d: usize, nq1d: usize) -> String {
    format!("hp_poisson_ne{ne}_nt{nt1d}_nq{nq1d}")
}

/// Build the unit-square mesh + assembled tensors for an artifact shape.
/// `ne` must be a perfect square (paper uses k x k grids).
pub fn square_domain(ne: usize, nt1d: usize, nq1d: usize)
    -> (QuadMesh, AssembledDomain) {
    let k = (ne as f64).sqrt().round() as usize;
    assert_eq!(k * k, ne, "ne={ne} is not a k x k grid");
    let mesh = generators::unit_square(k);
    let dom = assembly::assemble(&mesh, nt1d, nq1d, QuadKind::GaussLegendre);
    (mesh, dom)
}

/// Train a unit-square artifact on `problem`; returns (trainer report,
/// error norms on the paper's 100x100 grid).
pub struct SquareRun {
    pub report: crate::coordinator::trainer::TrainReport,
    pub errors: ErrorNorms,
    pub history: crate::coordinator::history::TrainHistory,
}

pub fn run_square(
    engine: &Engine,
    artifact: &str,
    ne: usize,
    nt1d: usize,
    nq1d: usize,
    problem: &dyn Problem,
    cfg: &TrainConfig,
) -> Result<SquareRun> {
    let (mesh, dom) = square_domain(ne, nt1d, nq1d);
    let src = DataSource {
        mesh: &mesh,
        domain: Some(&dom),
        problem,
        sensor_values: None,
    };
    let mut trainer = Trainer::new(engine, artifact, &src, cfg)?;
    let report = trainer.run()?;
    let grid = eval_grid(100, 100, 0.0, 0.0, 1.0, 1.0);
    let exact: Vec<f64> = grid
        .iter()
        .map(|p| problem.exact(p[0], p[1]).unwrap_or(0.0))
        .collect();
    let errors = trainer.evaluate(PREDICT_STD, &grid, &exact)?;
    Ok(SquareRun { report, errors, history: trainer.history.clone() })
}

/// Median time per training step measured over `iters` steps after
/// `warmup` steps — the paper's Fig. 2/10/16 protocol.
pub fn median_step_ms(
    engine: &Engine,
    artifact: &str,
    problem: &dyn Problem,
    iters: usize,
    warmup: usize,
) -> Result<f64> {
    let art = engine.load(artifact)?;
    let c = &art.manifest.config;
    let (mesh, dom) = square_domain(c.ne, c.nt1d, c.nq1d);
    let src = DataSource {
        mesh: &mesh,
        domain: Some(&dom),
        problem,
        sensor_values: None,
    };
    let cfg = TrainConfig { iters: 1, ..TrainConfig::default() };
    let mut t = Trainer::new(engine, artifact, &src, &cfg)?;
    for _ in 0..warmup {
        t.step_once()?;
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = std::time::Instant::now();
        t.step_once()?;
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    Ok(crate::util::stats::median(&samples))
}

/// PINN timing: same protocol, collocation artifact.
pub fn median_step_ms_pinn(
    engine: &Engine,
    artifact: &str,
    problem: &dyn Problem,
    iters: usize,
    warmup: usize,
) -> Result<f64> {
    let mesh = generators::unit_square(1);
    let src = DataSource {
        mesh: &mesh,
        domain: None,
        problem,
        sensor_values: None,
    };
    let cfg = TrainConfig { iters: 1, ..TrainConfig::default() };
    let mut t = Trainer::new(engine, artifact, &src, &cfg)?;
    for _ in 0..warmup {
        t.step_once()?;
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = std::time::Instant::now();
        t.step_once()?;
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    Ok(crate::util::stats::median(&samples))
}
