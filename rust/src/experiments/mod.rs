//! Experiment drivers: one module per table/figure of the paper
//! (DESIGN.md SS5). Each writes CSV series under `results/<id>/` and
//! prints the paper-comparable summary.

pub mod common;
pub mod fig02;
pub mod fig08;
pub mod fig09;
pub mod fig10;
pub mod fig11;
pub mod fig12;
pub mod fig14;
pub mod fig15;
pub mod fig16;
pub mod helmholtz;
pub mod table1;

use anyhow::{bail, Result};

use crate::util::cli::Args;

/// Every experiment id `repro experiment` accepts.
pub const ALL: &[&str] = &[
    "fig02", "fig08", "fig09", "fig10", "fig11", "fig12", "fig14",
    "fig15", "fig16", "helmholtz", "table1",
];

/// Dispatch an experiment by id.
pub fn run(id: &str, args: &Args) -> Result<()> {
    match id {
        "fig02" => fig02::run(args),
        "fig08" => fig08::run(args),
        "fig09" => fig09::run(args),
        "fig10" => fig10::run(args),
        "fig11" => fig11::run(args),
        "fig12" => fig12::run(args),
        "fig14" => fig14::run(args),
        "fig15" => fig15::run(args),
        "fig16" => fig16::run(args),
        "helmholtz" => helmholtz::run(args),
        "table1" => table1::run(args),
        "all" => {
            for e in ALL {
                println!("\n================ {e} ================");
                run(e, args)?;
            }
            Ok(())
        }
        other => bail!("unknown experiment '{other}' (known: {ALL:?})"),
    }
}
