//! Fig. 10: the headline efficiency figure.
//!
//! (a) median time/epoch vs residual points: FastVPINNs vs PINNs vs
//!     loop-based hp-VPINNs (the 100x claim);
//! (b) median time/epoch vs element count at constant total quadrature
//!     points (FastVPINNs ~flat, hp-VPINNs linear).
//!
//! FastVPINN timings come from whichever backend is selected; the PINN
//! and loop-hp baselines are AOT artifacts (xla backend) and are
//! recorded as NaN when unavailable.

use anyhow::Result;

use super::common::{self, ExpCtx};
use crate::problems::PoissonSin;
use crate::util::cli::Args;
use crate::util::csv::CsvWriter;

/// Run this experiment (see the module docs for what it
/// reproduces); results land under `results/`.
pub fn run(args: &Args) -> Result<()> {
    let ctx = ExpCtx::from_args(args)?;
    let iters = args.usize_or("timing-iters", 30)?;
    let warmup = args.usize_or("warmup", 3)?;
    let full = args.has("paper-scale");
    let dir = common::results_dir("fig10")?;
    let problem = PoissonSin::new(2.0 * std::f64::consts::PI);
    if ctx.is_native() {
        println!(
            "fig10 [native]: pinn/hp-loop baseline columns are NaN \
             (artifacts need --backend xla)"
        );
    }

    // ---- (a) residual-point sweep: 25 quad/elem, 25 test fns
    println!("fig10a: median step time vs residual points");
    let mut w = CsvWriter::create(
        dir.join("fig10a_residual_points.csv"),
        &["residual_points", "fastvpinn_ms", "pinn_ms", "hp_vpinn_ms"],
    )?;
    let ne_sweep: &[usize] = if full {
        &[16, 64, 256, 400, 1024]
    } else {
        &[16, 64, 256, 400]
    };
    for &ne in ne_sweep {
        let pts = ne * 25;
        let fv = common::median_step_ms_fv(&ctx, ne, 5, 5, &problem,
                                           iters, warmup)?;
        let (pinn, hp) = if ctx.is_native() {
            (f64::NAN, f64::NAN)
        } else {
            (
                common::median_step_ms_pinn(
                    &ctx, &format!("pinn_poisson_nc{pts}"), &problem,
                    iters, warmup)?,
                common::median_step_ms_hp(&ctx, ne, 5, 5, &problem,
                                          iters, warmup)?,
            )
        };
        println!("  pts={pts:<7} fv {fv:>8.3} ms | pinn {pinn:>8.3} ms | \
                  hp {hp:>9.3} ms | speedup hp/fv {:.1}x", hp / fv);
        w.row_f64(&[pts as f64, fv, pinn, hp])?;
    }
    w.flush()?;

    // ---- (b) element sweep at constant 6400 total quad points
    println!("fig10b: median step time vs elements (6400 quad total)");
    let mut w = CsvWriter::create(
        dir.join("fig10b_elements.csv"),
        &["ne", "nq1d", "fastvpinn_ms", "hp_vpinn_ms", "speedup"],
    )?;
    for (ne, nq) in [(1usize, 80usize), (4, 40), (16, 20), (64, 10),
                     (256, 5), (400, 4)] {
        let fv = common::median_step_ms_fv(&ctx, ne, 5, nq, &problem,
                                           iters, warmup)?;
        let hp = if ctx.is_native() {
            f64::NAN
        } else {
            common::median_step_ms_hp(&ctx, ne, 5, nq, &problem, iters,
                                      warmup)?
        };
        println!("  ne={ne:<5} fv {fv:>8.3} ms | hp {hp:>9.3} ms | \
                  {:.1}x", hp / fv);
        w.row_f64(&[ne as f64, nq as f64, fv, hp, hp / fv])?;
    }
    w.flush()?;
    println!("fig10 -> {}", dir.display());
    Ok(())
}
