//! Fig. 8: accuracy comparison, FastVPINNs vs PINNs on Poisson
//! omega = 2*pi (2x2 elements, 40^2 quad, 15^2 test fns vs 6400
//! collocation points; both 30x3 networks). The collocation PINN
//! baseline needs the xla backend; with the native backend only the
//! FastVPINNs row is produced.

use anyhow::Result;

use super::common::{self, ExpCtx};
use crate::coordinator::metrics::eval_grid;
use crate::coordinator::trainer::{DataSource, TrainConfig, Trainer};
use crate::mesh::generators;
use crate::problems::{PoissonSin, Problem};
use crate::util::cli::Args;
use crate::util::csv::CsvWriter;

/// Run this experiment (see the module docs for what it
/// reproduces); results land under `results/`.
pub fn run(args: &Args) -> Result<()> {
    let ctx = ExpCtx::from_args(args)?;
    // paper: 100k iters; CI default trains far fewer but records both
    let iters = args.usize_or("iters", 5000)?;
    let dir = common::results_dir("fig08")?;
    let problem = PoissonSin::new(2.0 * std::f64::consts::PI);
    let cfg = TrainConfig { iters, log_every: 50.max(iters / 200),
                            ..TrainConfig::default() };

    let mut w = CsvWriter::create(
        dir.join("summary.csv"),
        &["method", "backend", "iters", "final_loss", "mae", "rmse",
          "rel_l2", "linf", "median_ms"],
    )?;

    // ---- FastVPINNs (paper shape: ne=4, nt=15, nq=40)
    let fv = common::run_square(&ctx, 4, 15, 40, &problem, &cfg)?;
    fv.history.to_csv(dir.join("fastvpinn_history.csv"))?;
    println!(
        "FastVPINNs: loss {:.3e}, MAE {:.3e}, rel-L2 {:.3e}, \
         median {:.3} ms/step",
        fv.report.final_loss, fv.errors.mae, fv.errors.rel_l2,
        fv.report.median_step_ms
    );
    w.row(&["fastvpinn".into(), ctx.name().into(), iters.to_string(),
            format!("{:.6e}", fv.report.final_loss),
            format!("{:.6e}", fv.errors.mae),
            format!("{:.6e}", fv.errors.rmse),
            format!("{:.6e}", fv.errors.rel_l2),
            format!("{:.6e}", fv.errors.linf),
            format!("{:.4}", fv.report.median_step_ms)])?;

    // ---- PINN baseline (6400 collocation points, xla only)
    if ctx.is_native() {
        println!(
            "SKIP pinn baseline: collocation artifacts need --backend \
             xla (--features xla + make artifacts)"
        );
    } else {
        let mesh = generators::unit_square(1);
        let src = DataSource { mesh: &mesh, domain: None,
                               problem: &problem, sensor_values: None };
        let backend = ctx.make_xla_only("pinn_poisson_nc6400",
                                        Some(common::PREDICT_STD), &src,
                                        &cfg)?;
        let mut pinn = Trainer::new(backend, &cfg);
        let pinn_report = pinn.run()?;
        pinn.history.to_csv(dir.join("pinn_history.csv"))?;
        let grid = eval_grid(100, 100, 0.0, 0.0, 1.0, 1.0);
        let exact: Vec<f64> = grid
            .iter()
            .map(|p| problem.exact(p[0], p[1]).unwrap())
            .collect();
        let pinn_err = pinn.evaluate(&grid, &exact)?;
        println!(
            "PINNs:      loss {:.3e}, MAE {:.3e}, rel-L2 {:.3e}, \
             median {:.3} ms/step",
            pinn_report.final_loss, pinn_err.mae, pinn_err.rel_l2,
            pinn_report.median_step_ms
        );
        w.row(&["pinn".into(), ctx.name().into(), iters.to_string(),
                format!("{:.6e}", pinn_report.final_loss),
                format!("{:.6e}", pinn_err.mae),
                format!("{:.6e}", pinn_err.rmse),
                format!("{:.6e}", pinn_err.rel_l2),
                format!("{:.6e}", pinn_err.linf),
                format!("{:.4}", pinn_report.median_step_ms)])?;
    }
    w.flush()?;
    println!("fig08 -> {}", dir.display());
    Ok(())
}
