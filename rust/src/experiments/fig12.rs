//! Fig. 12: forward convection-diffusion on the spur-gear domain —
//! the complex-geometry showcase. FEM (our ParMooN stand-in) provides
//! the reference field; FastVPINNs trains on the same mesh. Fully
//! backend-portable: the native backend optimizes the same cd loss
//! (eps = 1, b = (0.1, 0)) with the paper's 3x50 network.

use anyhow::Result;

use super::common::{self, ExpCtx};
use crate::coordinator::metrics::ErrorNorms;
use crate::coordinator::schedule::LrSchedule;
use crate::coordinator::trainer::{DataSource, TrainConfig, Trainer};
use crate::fem::assembly;
use crate::fem::quadrature::QuadKind;
use crate::fem_solver;
use crate::mesh::{generators, vtk};
use crate::problems::GearCd;
use crate::runtime::backend::native::{NativeConfig, NativeLoss};
use crate::util::cli::Args;
use crate::util::csv::CsvWriter;

/// Run this experiment (see the module docs for what it
/// reproduces); results land under `results/`.
pub fn run(args: &Args) -> Result<()> {
    let ctx = ExpCtx::from_args(args)?;
    let iters = args.usize_or("iters", 1500)?;
    let paper = args.has("paper-scale");
    let dir = common::results_dir("fig12")?;
    let problem = GearCd;

    let mesh = if paper {
        generators::gear_paper()
    } else {
        generators::gear_ci()
    };
    println!("gear mesh: {} cells, {} points (paper: 14,192 cells)",
             mesh.n_cells(), mesh.n_points());

    // ---- FEM reference (the paper's "exact" solution source),
    // driven by the same Problem trait object as the training run
    let t0 = std::time::Instant::now();
    let fem = fem_solver::solve_problem(&mesh, &problem, 3)?;
    println!("FEM reference: {} CG/BiCGStab iters in {:.2}s",
             fem.solve_iterations, t0.elapsed().as_secs_f64());

    // ---- FastVPINNs training (paper: 3x50 net, lr 5e-3 x0.99/1000)
    let dom = assembly::assemble(&mesh, 4, 5, QuadKind::GaussLegendre);
    let src = DataSource { mesh: &mesh, domain: Some(&dom),
                           problem: &problem, sensor_values: None };
    let cfg = TrainConfig {
        iters,
        lr: LrSchedule::ExpDecay { lr0: 5e-3, factor: 0.99, every: 1000 },
        log_every: 50.max(iters / 100),
        ..TrainConfig::default()
    };
    let ncfg = NativeConfig {
        layers: vec![2, 50, 50, 50, 1],
        loss: NativeLoss::Forward,
        nb: 400,
        ns: 0,
    };
    let backend = ctx.make_backend(&ncfg, "fv_cd_gear",
                                   Some("predict_gear_16k"), &src, &cfg)?;
    let mut trainer = Trainer::new(backend, &cfg);
    let report = trainer.run()?;
    trainer.history.to_csv(dir.join("history.csv"))?;
    println!(
        "FastVPINNs: {} iters, final loss {:.3e}, median {:.2} ms/iter \
         (paper: ~13 ms/iter on A6000)",
        report.steps, report.final_loss, report.median_step_ms
    );

    // ---- compare at mesh nodes
    let pred = trainer.predict(&mesh.points)?;
    let errors = ErrorNorms::compute_f32(&pred, fem.nodal())?;
    println!("vs FEM: MAE {:.3e}, rel-L2 {:.3e}, Linf {:.3e}",
             errors.mae, errors.rel_l2, errors.linf);

    // ---- outputs: VTK fields + summary CSV
    let pred64: Vec<f64> = pred.iter().map(|&v| v as f64).collect();
    let err: Vec<f64> = pred64
        .iter()
        .zip(fem.nodal())
        .map(|(p, r)| (p - r).abs())
        .collect();
    vtk::write_point_fields(
        &mesh,
        &[("u_fem", fem.nodal()), ("u_fastvpinn", &pred64),
          ("abs_error", &err)],
        dir.join("gear_solution.vtk"),
    )?;

    let mut w = CsvWriter::create(
        dir.join("summary.csv"),
        &["n_cells", "iters", "final_loss", "mae", "rel_l2", "linf",
          "median_ms_per_iter", "fem_solve_secs", "total_quad_points"],
    )?;
    w.row_f64(&[mesh.n_cells() as f64, report.steps as f64,
                report.final_loss, errors.mae, errors.rel_l2,
                errors.linf, report.median_step_ms, fem.solve_seconds,
                (dom.ne * dom.nq) as f64])?;
    w.flush()?;
    println!("fig12 -> {}", dir.display());
    Ok(())
}
