//! Fig. 16: hyperparameter impact on median training time per epoch —
//! three 2D sweeps over (N_test, N_quad), (N_test, N_elem),
//! (N_quad, N_elem). Fully backend-portable (FastVPINN step only).

use anyhow::Result;

use super::common::{self, ExpCtx};
use crate::problems::PoissonSin;
use crate::util::cli::Args;
use crate::util::csv::CsvWriter;

pub fn run(args: &Args) -> Result<()> {
    let ctx = ExpCtx::from_args(args)?;
    let iters = args.usize_or("timing-iters", 20)?;
    let warmup = args.usize_or("warmup", 3)?;
    let dir = common::results_dir("fig16")?;
    let problem = PoissonSin::new(2.0 * std::f64::consts::PI);
    println!("fig16 backend: {}", ctx.name());

    // (a) N_test x N_quad at N_elem = 1
    println!("fig16a: nt x nq sweep (ne=1)");
    let mut w = CsvWriter::create(dir.join("fig16a_nt_nq.csv"),
                                  &["nt1d", "nq1d", "median_ms"])?;
    for nt in [5usize, 10, 20] {
        for nq in [10usize, 20, 40] {
            let ms = common::median_step_ms_fv(&ctx, 1, nt, nq, &problem,
                                               iters, warmup)?;
            println!("  nt={nt:<3} nq={nq:<3} {ms:.3} ms");
            w.row_f64(&[nt as f64, nq as f64, ms])?;
        }
    }
    w.flush()?;

    // (b) N_test x N_elem at nq1d = 10
    println!("fig16b: nt x ne sweep (nq=10x10)");
    let mut w = CsvWriter::create(dir.join("fig16b_nt_ne.csv"),
                                  &["nt1d", "ne", "median_ms"])?;
    for nt in [5usize, 10, 20] {
        for ne in [4usize, 64, 400] {
            let ms = common::median_step_ms_fv(&ctx, ne, nt, 10, &problem,
                                               iters, warmup)?;
            println!("  nt={nt:<3} ne={ne:<4} {ms:.3} ms");
            w.row_f64(&[nt as f64, ne as f64, ms])?;
        }
    }
    w.flush()?;

    // (c) N_quad x N_elem at nt1d = 10
    println!("fig16c: nq x ne sweep (nt=10x10)");
    let mut w = CsvWriter::create(dir.join("fig16c_nq_ne.csv"),
                                  &["nq1d", "ne", "median_ms"])?;
    for nq in [5usize, 10, 20] {
        for ne in [4usize, 64, 400] {
            let ms = common::median_step_ms_fv(&ctx, ne, 10, nq, &problem,
                                               iters, warmup)?;
            println!("  nq={nq:<3} ne={ne:<4} {ms:.3} ms");
            w.row_f64(&[nq as f64, ne as f64, ms])?;
        }
    }
    w.flush()?;
    println!("fig16 -> {}", dir.display());
    Ok(())
}
