//! Fig. 16: hyperparameter impact on median training time per epoch —
//! three 2D sweeps over (N_test, N_quad), (N_test, N_elem),
//! (N_quad, N_elem), plus a fourth sweep timing the two-head
//! inverse-space step (u + softplus'd eps head on the shared trunk)
//! against the plain forward step at the same grid sizes. Fully
//! backend-portable (FastVPINN step only); the inverse-space sweep
//! runs on the native backend (no AOT artifact sweep exists for the
//! two-head nets).

use anyhow::Result;

use super::common::{self, ExpCtx};
use crate::problems::PoissonSin;
use crate::util::cli::Args;
use crate::util::csv::CsvWriter;

/// Run this experiment (see the module docs for what it
/// reproduces); results land under `results/`.
pub fn run(args: &Args) -> Result<()> {
    let ctx = ExpCtx::from_args(args)?;
    let iters = args.usize_or("timing-iters", 20)?;
    let warmup = args.usize_or("warmup", 3)?;
    let dir = common::results_dir("fig16")?;
    let problem = PoissonSin::new(2.0 * std::f64::consts::PI);
    println!("fig16 backend: {}", ctx.name());

    // (a) N_test x N_quad at N_elem = 1
    println!("fig16a: nt x nq sweep (ne=1)");
    let mut w = CsvWriter::create(dir.join("fig16a_nt_nq.csv"),
                                  &["nt1d", "nq1d", "median_ms"])?;
    for nt in [5usize, 10, 20] {
        for nq in [10usize, 20, 40] {
            let ms = common::median_step_ms_fv(&ctx, 1, nt, nq, &problem,
                                               iters, warmup)?;
            println!("  nt={nt:<3} nq={nq:<3} {ms:.3} ms");
            w.row_f64(&[nt as f64, nq as f64, ms])?;
        }
    }
    w.flush()?;

    // (b) N_test x N_elem at nq1d = 10
    println!("fig16b: nt x ne sweep (nq=10x10)");
    let mut w = CsvWriter::create(dir.join("fig16b_nt_ne.csv"),
                                  &["nt1d", "ne", "median_ms"])?;
    for nt in [5usize, 10, 20] {
        for ne in [4usize, 64, 400] {
            let ms = common::median_step_ms_fv(&ctx, ne, nt, 10, &problem,
                                               iters, warmup)?;
            println!("  nt={nt:<3} ne={ne:<4} {ms:.3} ms");
            w.row_f64(&[nt as f64, ne as f64, ms])?;
        }
    }
    w.flush()?;

    // (c) N_quad x N_elem at nt1d = 10
    println!("fig16c: nq x ne sweep (nt=10x10)");
    let mut w = CsvWriter::create(dir.join("fig16c_nq_ne.csv"),
                                  &["nq1d", "ne", "median_ms"])?;
    for nq in [5usize, 10, 20] {
        for ne in [4usize, 64, 400] {
            let ms = common::median_step_ms_fv(&ctx, ne, 10, nq, &problem,
                                               iters, warmup)?;
            println!("  nq={nq:<3} ne={ne:<4} {ms:.3} ms");
            w.row_f64(&[nq as f64, ne as f64, ms])?;
        }
    }
    w.flush()?;

    // (d) forward vs two-head inverse-space step at nt1d=5, nq1d=5
    if ctx.is_native() {
        println!("fig16d: forward vs two-head inverse-space step (native)");
        let mut w = CsvWriter::create(
            dir.join("fig16d_inverse_space.csv"),
            &["ne", "forward_median_ms", "inverse_space_median_ms"])?;
        for k in [2usize, 8, 20] {
            let fwd = common::native_step_case(k, 5, 5, iters, warmup)?;
            let inv = common::native_inverse_space_step_case(
                k, 5, 5, iters, warmup)?;
            println!("  ne={:<4} forward {:.3} ms, inverse_space {:.3} ms",
                     k * k, fwd.summary.median, inv.summary.median);
            w.row_f64(&[(k * k) as f64, fwd.summary.median,
                        inv.summary.median])?;
        }
        w.flush()?;
    } else {
        println!("fig16d SKIP on xla: the two-head sweep times the \
                  native InverseSpace step");
    }
    println!("fig16 -> {}", dir.display());
    Ok(())
}
