//! Helmholtz high-frequency sweep — the frequency-scaling study (in
//! the spirit of the paper's omega sweeps, SS4.6) on the *reaction*
//! path of the variational form: `-lap u - k^2 u = f` with
//! `u = sin(kx) sin(ky)` for k = 2pi, 4pi (+ 8pi at `--paper-scale`)
//! on a fixed coarse 2x2 mesh with high-order tests — the paper's
//! protocol scales the frequency, not the mesh, and the coarse mesh
//! keeps the per-element forcing projections (the variational signal)
//! strong against the boundary penalty while the forcing itself grows
//! with k^2. Every case rides the same tensorized kernel as
//! Poisson — `c = -k^2` is one hoisted coefficient — so the sweep
//! tracks accuracy and median step time as the wavenumber grows.
//!
//! Writes `results/helmholtz/sweep.csv`.

use anyhow::Result;

use super::common::{self, run_square, ExpCtx};
use crate::coordinator::schedule::LrSchedule;
use crate::coordinator::trainer::TrainConfig;
use crate::problems::Helmholtz2D;
use crate::util::cli::Args;
use crate::util::csv::CsvWriter;

/// Run this experiment (see the module docs for what it
/// reproduces); results land under `results/`.
pub fn run(args: &Args) -> Result<()> {
    let ctx = ExpCtx::from_args(args)?;
    // run_square's XLA path would execute the *Poisson* AOT artifact
    // (the PDE is baked into a compiled train step) and silently label
    // it Helmholtz — skip on xla (the artifact-less-experiment
    // convention, so `experiment all` keeps going) until a Helmholtz
    // artifact exists
    if !ctx.is_native() {
        println!(
            "helmholtz SKIP on xla: the sweep trains the native \
             generalized-form step; no Helmholtz AOT artifact exists"
        );
        return Ok(());
    }
    let iters = args.usize_or("iters", 12_000)?;
    let paper = args.has("paper-scale");
    let dir = common::results_dir("helmholtz")?;

    let multipliers: &[f64] =
        if paper { &[2.0, 4.0, 8.0] } else { &[2.0, 4.0] };
    // fixed coarse mesh (the CLI train default for helmholtz): the
    // wavenumber scales, the discretization stays (nq1d = 10 resolves
    // up to ~2 periods per element direction)
    let n = args.usize_or("n", 2)?;

    let mut w = CsvWriter::create(
        dir.join("sweep.csv"),
        &["k_over_pi", "ne", "iters", "final_loss", "mae", "rel_l2",
          "linf", "median_ms_per_iter", "total_secs"],
    )?;
    println!("Helmholtz frequency sweep [{} backend], {iters} iters/case",
             ctx.name());
    for &m in multipliers {
        let k = m * std::f64::consts::PI;
        let problem = Helmholtz2D::new(k);
        let ne = n * n;
        // the registry's helmholtz training defaults: escape the
        // early boundary-dominated saddle at full rate, then decay to
        // damp the late rel-L2 wander (see problems::registry)
        let cfg = TrainConfig {
            iters,
            lr: LrSchedule::ExpDecay { lr0: 5e-3, factor: 0.7,
                                       every: 1500 },
            log_every: 200.max(iters / 20),
            ..TrainConfig::default()
        };
        let run = run_square(&ctx, ne, 5, 10, &problem, &cfg)?;
        println!(
            "  k = {m:.0}*pi  ne={ne:<5} loss {:.3e}  rel-L2 {:.3e}  \
             median {:.3} ms/step",
            run.report.final_loss, run.errors.rel_l2,
            run.report.median_step_ms
        );
        run.history
            .to_csv(dir.join(format!("history_k{m:.0}pi.csv")))?;
        w.row_f64(&[m, ne as f64, run.report.steps as f64,
                    run.report.final_loss, run.errors.mae,
                    run.errors.rel_l2, run.errors.linf,
                    run.report.median_step_ms,
                    run.report.total_seconds])?;
    }
    w.flush()?;
    println!("helmholtz -> {}", dir.display());
    Ok(())
}
