//! Golden-trace end-to-end tests of the observability plane.
//!
//! The heart of the tier: the zero-perturbation invariant. A training
//! run with `--metrics-out` armed must produce *bit-identical*
//! per-step losses and final predictions to the same run without it —
//! telemetry observes the trajectory, it never participates in it.
//! The stream itself is validated line by line against the v1 schema:
//! contiguous step ids, finite phase times that sum to no more than
//! the step wall time, a `flush` line last.
//!
//! Telemetry is a process-global (one stream per process), so exactly
//! one in-process test arms it — the same single-owner discipline as
//! the failpoint tests. The CLI tests spawn `repro` subprocesses and
//! can run concurrently.

use fastvpinns::coordinator::metrics::eval_grid;
use fastvpinns::coordinator::schedule::LrSchedule;
use fastvpinns::coordinator::trainer::{DataSource, TrainConfig, Trainer};
use fastvpinns::fem::assembly;
use fastvpinns::fem::quadrature::QuadKind;
use fastvpinns::mesh::generators;
use fastvpinns::problems::PoissonSin;
use fastvpinns::runtime::backend::native::{
    NativeBackend, NativeConfig, NativeLoss,
};
use fastvpinns::runtime::backend::BackendOpts;
use fastvpinns::runtime::checkpoint::hash_f32_bits;
use fastvpinns::telemetry::SCHEMA_VERSION;
use fastvpinns::util::json::Json;

const ITERS: usize = 300;

/// One standard small poisson_sin training run: per-step losses
/// (log_every = 1) and the u-hash over a fixed grid.
fn train_once() -> (Vec<f64>, u64) {
    let problem = PoissonSin::new(std::f64::consts::PI);
    let mesh = generators::unit_square(2);
    let dom = assembly::assemble(&mesh, 3, 8, QuadKind::GaussLegendre);
    let src = DataSource {
        mesh: &mesh,
        domain: Some(&dom),
        problem: &problem,
        sensor_values: None,
    };
    let cfg = TrainConfig {
        iters: ITERS,
        lr: LrSchedule::Constant(1e-2),
        log_every: 1,
        seed: 11,
        ..TrainConfig::default()
    };
    let ncfg = NativeConfig {
        layers: vec![2, 16, 16, 1],
        loss: NativeLoss::Forward,
        nb: 80,
        ns: 0,
    };
    let backend =
        NativeBackend::new(&ncfg, &src, &BackendOpts::from(&cfg)).unwrap();
    let mut t = Trainer::new(Box::new(backend), &cfg);
    t.run().unwrap();
    let losses: Vec<f64> = t.history.rows.iter().map(|r| r.loss).collect();
    let grid = eval_grid(20, 20, 0.0, 0.0, 1.0, 1.0);
    let u = t.predict(&grid).unwrap();
    (losses, hash_f32_bits(&u))
}

fn tag(ev: &Json) -> &str {
    ev.req("ev").unwrap().as_str().unwrap()
}

#[test]
fn golden_trace_bit_identical_and_stream_schema_valid() {
    // disarmed reference trajectory
    let (ref_losses, ref_hash) = train_once();
    assert_eq!(ref_losses.len(), ITERS);

    // identical run with the recorder armed
    let path = std::env::temp_dir().join(format!(
        "fastvpinns_telemetry_e2e_{}.jsonl",
        std::process::id()
    ));
    fastvpinns::telemetry::arm(&path).unwrap();
    let (armed_losses, armed_hash) = train_once();
    fastvpinns::telemetry::shutdown();
    let text = std::fs::read_to_string(&path).unwrap();
    let _ = std::fs::remove_file(&path);

    // ---- zero-perturbation invariant: bit-identical trajectory
    assert_eq!(armed_losses.len(), ITERS);
    for (i, (a, b)) in ref_losses.iter().zip(&armed_losses).enumerate()
    {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "step {}: loss diverged under telemetry ({a} vs {b})",
            i + 1
        );
    }
    assert_eq!(
        ref_hash, armed_hash,
        "final u-hash diverged under telemetry"
    );

    // ---- stream validation
    assert!(text.ends_with('\n'), "stream must end with a newline");
    let events: Vec<Json> = text
        .lines()
        .map(|l| {
            Json::parse(l)
                .unwrap_or_else(|e| panic!("unparseable line {l:?}: {e}"))
        })
        .collect();
    // arm stamps the kernel line first; clean shutdown appends flush
    assert_eq!(tag(events.first().unwrap()), "kernel");
    assert_eq!(tag(events.last().unwrap()), "flush");
    assert_eq!(
        events
            .last()
            .unwrap()
            .req("dropped")
            .unwrap()
            .as_usize()
            .unwrap(),
        0,
        "no events may be dropped at this rate"
    );
    // every line carries the schema version; timestamps are monotone
    // (the writer preserves emit order)
    let mut last_t = -1.0f64;
    for ev in &events {
        assert_eq!(
            ev.req("v").unwrap().as_usize().unwrap() as u32,
            SCHEMA_VERSION
        );
        if tag(ev) != "flush" {
            let t = ev.req("t_ms").unwrap().as_f64().unwrap();
            assert!(t.is_finite() && t >= 0.0, "bad t_ms {t}");
            assert!(t >= last_t, "t_ms went backwards: {t} < {last_t}");
            last_t = t;
        }
    }
    // a healthy forward run has exactly the arm line, the steps and
    // the flush — no recoveries, no checkpoints
    assert!(events
        .iter()
        .all(|e| !matches!(tag(e), "recovery" | "checkpoint")));

    // ---- per-step events: contiguous ids, coherent phases, and the
    // stream's losses are the history's, bit for bit (floats are
    // serialized shortest-roundtrip)
    let steps: Vec<&Json> =
        events.iter().filter(|e| tag(e) == "step").collect();
    assert_eq!(steps.len(), ITERS);
    for (i, ev) in steps.iter().enumerate() {
        assert_eq!(
            ev.req("step").unwrap().as_usize().unwrap(),
            i + 1,
            "step ids must be contiguous on a clean run"
        );
        let wall = ev.req("wall_ms").unwrap().as_f64().unwrap();
        assert!(wall.is_finite() && wall >= 0.0, "wall_ms {wall}");
        let mut phase_sum = 0.0;
        for k in ["assign_ms", "step_ms", "reduce_ms", "sync_ms"] {
            let v = ev
                .req(k)
                .unwrap()
                .as_f64()
                .unwrap_or_else(|_| {
                    panic!(
                        "step {}: {k} null — the native backend must \
                         publish phase times when armed",
                        i + 1
                    )
                });
            assert!(v.is_finite() && v >= 0.0, "{k} = {v}");
            phase_sum += v;
        }
        assert!(
            phase_sum <= wall * (1.0 + 1e-9) + 1e-6,
            "step {}: phase sum {phase_sum} ms exceeds step wall \
             {wall} ms",
            i + 1
        );
        let loss = ev.req("loss").unwrap().as_f64().unwrap();
        assert_eq!(
            loss.to_bits(),
            armed_losses[i].to_bits(),
            "step {}: stream loss {loss} is not the trajectory loss",
            i + 1
        );
        let lr = ev.req("lr").unwrap().as_f64().unwrap();
        assert!((lr - 1e-2).abs() < 1e-15, "lr {lr}");
        let g = ev.req("grad_norm").unwrap().as_f64().unwrap();
        assert!(g.is_finite() && g >= 0.0, "grad_norm {g}");
    }
}

#[test]
fn cli_metrics_out_stream_parses_and_report_reads_it() {
    let dir = std::env::temp_dir().join(format!(
        "fastvpinns_telemetry_cli_{}",
        std::process::id()
    ));
    std::fs::create_dir_all(&dir).unwrap();
    let metrics = dir.join("train.jsonl");
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_repro"))
        .args([
            "train",
            "--problem",
            "poisson_sin",
            "--n",
            "2",
            "--nt1d",
            "3",
            "--nq1d",
            "6",
            "--layers",
            "2,8,1",
            "--iters",
            "40",
            "--metrics-out",
        ])
        .arg(&metrics)
        .env("FASTVPINNS_THREADS", "2")
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "train --metrics-out failed:\n{}",
        String::from_utf8_lossy(&out.stderr)
    );

    let text = std::fs::read_to_string(&metrics).unwrap();
    assert!(text.ends_with('\n'));
    let events: Vec<Json> =
        text.lines().map(|l| Json::parse(l).unwrap()).collect();
    let n_steps = events.iter().filter(|e| tag(e) == "step").count();
    assert_eq!(n_steps, 40, "one step event per iteration");
    assert_eq!(tag(events.last().unwrap()), "flush");

    // and the report subcommand digests the stream
    let rep = std::process::Command::new(env!("CARGO_BIN_EXE_repro"))
        .arg("report")
        .arg(&metrics)
        .output()
        .unwrap();
    let _ = std::fs::remove_dir_all(&dir);
    assert!(
        rep.status.success(),
        "repro report failed:\n{}",
        String::from_utf8_lossy(&rep.stderr)
    );
    let stdout = String::from_utf8_lossy(&rep.stdout);
    assert!(
        stdout.contains("step wall time"),
        "report missing step summary:\n{stdout}"
    );
    assert!(stdout.contains("phase breakdown"), "{stdout}");
}
