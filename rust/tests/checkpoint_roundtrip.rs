//! End-to-end tests of the checkpoint + batched-inference subsystem:
//! train → export → import must reproduce predictions bit-for-bit, a
//! warm restart must continue the uninterrupted run's loss trajectory
//! exactly, and malformed artifacts (corruption, truncation, wrong
//! version) must be rejected with clear errors — never a panic. All
//! tiny configurations, fast enough for the debug-mode default suite.

use std::path::PathBuf;

use fastvpinns::coordinator::schedule::LrSchedule;
use fastvpinns::coordinator::trainer::{
    CheckpointPolicy, DataSource, TrainConfig, Trainer,
};
use fastvpinns::fem::assembly::{self, AssembledDomain};
use fastvpinns::fem::quadrature::QuadKind;
use fastvpinns::mesh::{generators, QuadMesh};
use fastvpinns::problems::{Helmholtz2D, InverseSpaceSin, Problem};
use fastvpinns::runtime::backend::native::{
    NativeBackend, NativeConfig, NativeLoss,
};
use fastvpinns::runtime::backend::{Backend, BackendOpts};
use fastvpinns::runtime::checkpoint::Checkpoint;
use fastvpinns::runtime::infer::InferenceSession;

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join("fastvpinns_ckpt_e2e");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(format!("{}_{name}", std::process::id()))
}

/// Small Helmholtz setup: exercises a reaction-term form (a constant
/// `c` coefficient travels through the artifact) on a 2x2 mesh.
fn setup() -> (QuadMesh, AssembledDomain, Helmholtz2D) {
    let mesh = generators::unit_square(2);
    let dom = assembly::assemble(&mesh, 2, 4, QuadKind::GaussLegendre);
    (mesh, dom, Helmholtz2D::new(std::f64::consts::PI))
}

fn trainer<'a>(
    mesh: &'a QuadMesh,
    dom: &'a AssembledDomain,
    problem: &'a dyn Problem,
    loss: NativeLoss,
    ns: usize,
    cfg: &TrainConfig,
) -> Trainer<'a> {
    let src = DataSource {
        mesh,
        domain: Some(dom),
        problem,
        sensor_values: None,
    };
    let ncfg = NativeConfig {
        layers: vec![2, 10, 1],
        loss,
        nb: 24,
        ns,
    };
    let backend =
        NativeBackend::new(&ncfg, &src, &BackendOpts::from(cfg)).unwrap();
    Trainer::new(Box::new(backend), cfg)
}

#[test]
fn train_export_import_predicts_bit_identically() {
    let (mesh, dom, problem) = setup();
    let cfg = TrainConfig { iters: 40, ..TrainConfig::default() };
    let mut t = trainer(&mesh, &dom, &problem, NativeLoss::Forward, 0,
                        &cfg);
    t.run().unwrap();
    let path = tmp("roundtrip.ckpt");
    let mut ck = t.checkpoint().unwrap();
    ck.problem = "helmholtz".into();
    ck.write(&path).unwrap();

    // a fixed query cloud, deliberately not the training points
    let pts: Vec<[f64; 2]> = (0..301)
        .map(|i| {
            let s = i as f64 / 300.0;
            [s, (0.5 + 0.37 * s).fract()]
        })
        .collect();
    let want = t.predict(&pts).unwrap();

    let mut sess = InferenceSession::open(&path).unwrap();
    assert_eq!(sess.problem, "helmholtz");
    assert!(!sess.two_head());
    let (got, eps) = sess.eval(&pts);
    assert!(eps.is_none());
    // bit-for-bit: raw f64 weights + the same blocked forward path
    assert_eq!(got, want);
    std::fs::remove_file(&path).ok();
}

#[test]
fn warm_restart_continues_the_loss_trajectory_exactly() {
    let (mesh, dom, problem) = setup();
    let lr = LrSchedule::ExpDecay { lr0: 5e-3, factor: 0.5, every: 20 };

    // uninterrupted reference: 60 steps, losses recorded per step
    let cfg_a = TrainConfig {
        iters: 60,
        lr,
        log_every: 1,
        ..TrainConfig::default()
    };
    let mut a = trainer(&mesh, &dom, &problem, NativeLoss::Forward, 0,
                        &cfg_a);
    a.run().unwrap();
    let ref_losses: Vec<f64> =
        a.history.rows.iter().map(|r| r.loss).collect();
    assert_eq!(ref_losses.len(), 60);

    // interrupted run: 30 steps, checkpoint, then resume 30 more
    let cfg_b = TrainConfig { iters: 30, ..cfg_a.clone() };
    let mut b = trainer(&mesh, &dom, &problem, NativeLoss::Forward, 0,
                        &cfg_b);
    b.run().unwrap();
    let ck = b.checkpoint().unwrap();
    assert_eq!(ck.step, 30);
    // through the on-disk format, as a real restart would
    let ck = Checkpoint::from_bytes(&ck.to_bytes()).unwrap();

    let src = DataSource {
        mesh: &mesh,
        domain: Some(&dom),
        problem: &problem,
        sensor_values: None,
    };
    let resumed = NativeBackend::from_checkpoint(&ck, &src).unwrap();
    let mut c = Trainer::new(Box::new(resumed), &cfg_b);
    c.resume_from_step(ck.step);
    c.run().unwrap();

    // the resumed half must be bit-identical to steps 31..60 of the
    // uninterrupted run: same Adam state, same step numbering, same
    // LR-schedule position, same re-drawn boundary samples
    let resumed_losses: Vec<f64> =
        c.history.rows.iter().map(|r| r.loss).collect();
    assert_eq!(resumed_losses.len(), 30);
    for (i, (ra, rb)) in ref_losses[30..]
        .iter()
        .zip(&resumed_losses)
        .enumerate()
    {
        assert_eq!(
            ra.to_bits(),
            rb.to_bits(),
            "step {}: uninterrupted {ra:.17e} vs resumed {rb:.17e}",
            31 + i
        );
    }
    // and the final parameters agree bitwise across both runs
    let pts = [[0.3, 0.3], [0.7, 0.2]];
    assert_eq!(a.predict(&pts).unwrap(), c.predict(&pts).unwrap());
}

#[test]
fn two_head_checkpoint_roundtrips_eps_field() {
    let mesh = generators::unit_square(1);
    let dom = assembly::assemble(&mesh, 2, 4, QuadKind::GaussLegendre);
    let problem = InverseSpaceSin;
    let cfg = TrainConfig { iters: 15, ..TrainConfig::default() };
    let mut t = trainer(&mesh, &dom, &problem, NativeLoss::InverseSpace,
                        10, &cfg);
    t.run().unwrap();
    let path = tmp("two_head.ckpt");
    t.checkpoint().unwrap().write(&path).unwrap();
    let mut sess = InferenceSession::open(&path).unwrap();
    assert!(sess.two_head());
    let pts = [[0.1, 0.9], [0.6, 0.6], [0.9, 0.2]];
    let (u, eps) = sess.eval(&pts);
    let heads = t.predict_heads(&pts).unwrap();
    assert_eq!(u, heads[0]);
    assert_eq!(eps.unwrap(), heads[1]);
    std::fs::remove_file(&path).ok();
}

#[test]
fn trainer_policy_resume_via_best_artifact() {
    // the .best artifact a CheckpointPolicy writes is itself a valid
    // warm-restart source
    let (mesh, dom, problem) = setup();
    let cfg = TrainConfig { iters: 20, ..TrainConfig::default() };
    let mut t = trainer(&mesh, &dom, &problem, NativeLoss::Forward, 0,
                        &cfg);
    let path = tmp("policy.ckpt");
    t.set_checkpoint_policy(CheckpointPolicy {
        path: path.clone(),
        every: 0,
        problem: "helmholtz".into(),
        cli: vec![("k-pi".into(), "1".into()), ("n".into(), "2".into())],
    });
    let report = t.run().unwrap();
    assert!(report.best_metric.is_some());
    let best = {
        let mut b = path.clone().into_os_string();
        b.push(".best");
        PathBuf::from(b)
    };
    let ck = Checkpoint::read(&best).unwrap();
    assert_eq!(ck.problem, "helmholtz");
    assert_eq!(ck.cli.len(), 2);
    let src = DataSource {
        mesh: &mesh,
        domain: Some(&dom),
        problem: &problem,
        sensor_values: None,
    };
    let mut resumed = NativeBackend::from_checkpoint(&ck, &src).unwrap();
    assert_eq!(resumed.loss_kind(), "helmholtz");
    resumed.step(ck.step + 1, 1e-3).unwrap();
    std::fs::remove_file(&path).ok();
    std::fs::remove_file(&best).ok();
}

#[test]
fn malformed_artifacts_error_instead_of_panicking() {
    let (mesh, dom, problem) = setup();
    let cfg = TrainConfig { iters: 3, ..TrainConfig::default() };
    let mut t = trainer(&mesh, &dom, &problem, NativeLoss::Forward, 0,
                        &cfg);
    t.run().unwrap();
    let bytes = t.checkpoint().unwrap().to_bytes();

    // single-bit corruption anywhere must be caught by the checksum
    for frac in [0.2, 0.5, 0.9] {
        let mut bad = bytes.clone();
        let i = (bad.len() as f64 * frac) as usize;
        bad[i] ^= 0x01;
        let err = Checkpoint::from_bytes(&bad).unwrap_err();
        assert!(
            err.to_string().contains("corrupted")
                || err.to_string().contains("not a FastVPINNs"),
            "byte {i}: {err}"
        );
    }
    // truncation
    assert!(Checkpoint::from_bytes(&bytes[..bytes.len() / 2]).is_err());
    // a non-checkpoint file read through the public path
    let path = tmp("not_a_checkpoint.bin");
    std::fs::write(&path, b"definitely not a checkpoint").unwrap();
    let err = Checkpoint::read(&path).unwrap_err();
    assert!(format!("{err:#}").contains("checkpoint"), "{err:#}");
    std::fs::remove_file(&path).ok();
    // missing file: error mentions the path, still no panic
    assert!(Checkpoint::read(tmp("missing.ckpt")).is_err());
}

#[test]
fn resume_on_a_different_domain_is_rejected() {
    let (mesh, dom, problem) = setup();
    let cfg = TrainConfig { iters: 3, ..TrainConfig::default() };
    let mut t = trainer(&mesh, &dom, &problem, NativeLoss::Forward, 0,
                        &cfg);
    t.run().unwrap();
    let ck = t.checkpoint().unwrap();

    // same problem, different quadrature order -> different fingerprint
    let dom2 = assembly::assemble(&mesh, 2, 5, QuadKind::GaussLegendre);
    let src = DataSource {
        mesh: &mesh,
        domain: Some(&dom2),
        problem: &problem,
        sensor_values: None,
    };
    let err = NativeBackend::from_checkpoint(&ck, &src).unwrap_err();
    assert!(err.to_string().contains("fingerprint"), "{err}");

    // different PDE coefficients (k) under the same mesh -> form error
    let other = Helmholtz2D::new(2.0 * std::f64::consts::PI);
    let src = DataSource {
        mesh: &mesh,
        domain: Some(&dom),
        problem: &other,
        sensor_values: None,
    };
    let err = NativeBackend::from_checkpoint(&ck, &src).unwrap_err();
    assert!(err.to_string().contains("coefficients"), "{err}");
}
