//! Chaos tier: drives the crash-safe, self-healing runtime through the
//! real `repro` CLI with failpoints armed — the faults fire inside the
//! production code paths (mid-save, mid-step, mid-kernel), not in a
//! mock. Everything here is `#[ignore]`d: the scenarios spawn release
//! binaries and train for real step budgets, so the CI `chaos` job
//! runs them in release via `--include-ignored` while the debug-mode
//! default suite skips them.
//!
//! Scenarios (the PR's acceptance criteria):
//! - `grad.nan@500` mid-run, twice: on `poisson_sin` the divergence
//!   sentinel rolls back to the last in-memory snapshot, backs off the
//!   LR, and the run converges (family-sized 1e-1 bar — see the test
//!   doc for why constant-LR poisson wanders); on `helmholtz` the
//!   healed run must still meet the repo's existing rel-L2 < 1e-2
//!   acceptance bar, backed by the anneal that restores the LR scale
//!   after sustained health.
//! - `checkpoint.write.kill@k` at every save point: the generation
//!   ring keeps a loadable artifact through a crash at any completed
//!   save; a crash before the *first* save ever completes fails the
//!   later `--resume` with a clear salvage error, never a panic.
//! - `kernel.avx2.fault` mid-run: dispatch degrades to the scalar
//!   kernels and the continuation is bit-identical to a forced-scalar
//!   run resumed from the same ring artifact.
//! - `step.stall` + `--watchdog-ms`: a stalled step is flagged
//!   (warn-only) and counted in the report.
//! - divergence rollback with the persistent worker pool: the replay
//!   after a NaN-contaminated step on a reused pool is bit-identical
//!   to a fresh backend restored from the same snapshot (no stale
//!   per-worker state survives a recovery).

use std::path::{Path, PathBuf};
use std::process::{Command, Output};

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("fastvpinns_chaos_{tag}_{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Run the repro binary with pinned threading. The f64 reduction
/// order is worker-count-independent (fixed-order shard tree reduce),
/// so the pin is purely about not oversubscribing shared CI runners.
fn repro(args: &[&str], envs: &[(&str, &str)]) -> Output {
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_repro"));
    cmd.args(args).env("FASTVPINNS_THREADS", "2");
    for (k, v) in envs {
        cmd.env(k, v);
    }
    cmd.output().expect("spawn repro")
}

fn stdout_of(out: &Output) -> String {
    String::from_utf8_lossy(&out.stdout).into_owned()
}

fn stderr_of(out: &Output) -> String {
    String::from_utf8_lossy(&out.stderr).into_owned()
}

/// Extract the 16-hex-digit quad-point u hash a checkpointing train
/// run prints ("... quad-point u hash <hash> over N points").
fn u_hash(stdout: &str) -> String {
    stdout
        .lines()
        .filter_map(|l| l.split("u hash ").nth(1))
        .filter_map(|rest| rest.split_whitespace().next())
        .last()
        .unwrap_or_else(|| panic!("no 'u hash' line in:\n{stdout}"))
        .to_string()
}

/// (a1) Injected NaN gradient at step 500 on `poisson_sin`: the run
/// must recover via rollback + LR backoff and converge. The bar here
/// is 1e-1, not 1e-2: constant-LR poisson_sin has a chaotic
/// saddle-escape time and an endgame wander floor measured at
/// 1.5e-2..5.4e-2 across exact-Rust-seed replicas (clean *and*
/// healed families — python/proto_selfheal.py), so 1e-1 is the
/// converged-sanity check with 2x margin over the worst family draw
/// while still cleanly separating recovery (~3e-2) from a dead run
/// (rel-L2 ~1.0 or NaN).
#[test]
#[ignore = "release-mode chaos tier (CI chaos job)"]
fn grad_nan_recovers_on_poisson_and_converges() {
    let out = repro(
        &[
            "train",
            "--problem", "poisson_sin",
            "--failpoints", "grad.nan@500",
            "--expect-rel-l2", "1e-1",
        ],
        &[],
    );
    let (so, se) = (stdout_of(&out), stderr_of(&out));
    assert!(
        out.status.success(),
        "run failed\nstdout:\n{so}\nstderr:\n{se}"
    );
    assert!(
        se.contains("recovery[1/"),
        "no recovery line on stderr:\n{se}"
    );
    assert!(
        so.contains("recoveries: 1"),
        "report missing the recovery record:\n{so}"
    );
    assert!(
        so.contains("rolled back to"),
        "recovery summary missing:\n{so}"
    );
}

/// (a2) The same fault on `helmholtz` — the problem whose rel-L2 <
/// 1e-2 bar CI already enforces on clean runs — must recover AND
/// still meet that existing bar. This is what makes the backoff
/// anneal load-bearing: exact-seed replays (python/proto_selfheal.py)
/// put the healed+anneal family at 4.6e-3..6.9e-3 (seeds 42/1/7),
/// while a *permanent* 0.5 backoff drifts to 1.02e-2 on seed 1 —
/// over the bar.
#[test]
#[ignore = "release-mode chaos tier (CI chaos job)"]
fn grad_nan_recovery_still_meets_the_helmholtz_bar() {
    let out = repro(
        &[
            "train",
            "--problem", "helmholtz",
            "--failpoints", "grad.nan@500",
            "--expect-rel-l2", "1e-2",
        ],
        &[],
    );
    let (so, se) = (stdout_of(&out), stderr_of(&out));
    assert!(
        out.status.success(),
        "healed run missed the existing accuracy bar\n\
         stdout:\n{so}\nstderr:\n{se}"
    );
    assert!(
        se.contains("recovery[1/"),
        "no recovery line on stderr:\n{se}"
    );
    assert!(
        se.contains("lr scale restored to 1.0"),
        "backoff anneal did not fire:\n{se}"
    );
    assert!(
        so.contains("recoveries: 1"),
        "report missing the recovery record:\n{so}"
    );
}

/// (b) Crash (exit 137) injected at the k-th checkpoint write, for
/// every save point of the run: any completed save must stay
/// salvageable through the generation ring; a crash during the very
/// first save (nothing durable yet) must fail the resume with the
/// clear salvage error listing every candidate — never a panic.
#[test]
#[ignore = "release-mode chaos tier (CI chaos job)"]
fn checkpoint_kill_never_loses_a_completed_save() {
    // write call order in this run: primary@100 (hit 1), best@100
    // (hit 2 — first save always improves on +inf), primary@200,
    // best@200 or primary@300, ... — hits 1..=4 all exist.
    for k in 1..=4u32 {
        let dir = tmp_dir(&format!("kill{k}"));
        let ckpt = dir.join("out.ckpt");
        let ckpt_s = ckpt.to_str().unwrap();
        let fp = format!("checkpoint.write.kill@{k}");
        let out = repro(
            &[
                "train",
                "--problem", "poisson_sin",
                "--iters", "300",
                "--layers", "2,16,1",
                "--nb", "64",
                "--checkpoint", ckpt_s,
                "--checkpoint-every", "100",
                "--failpoints", &fp,
            ],
            &[],
        );
        assert_eq!(
            out.status.code(),
            Some(137),
            "kill@{k} did not kill the run\nstderr:\n{}",
            stderr_of(&out)
        );
        let resume = repro(
            &["train", "--resume", ckpt_s, "--iters", "20"],
            &[],
        );
        let (so, se) = (stdout_of(&resume), stderr_of(&resume));
        if k == 1 {
            // the very first write was torn and nothing else exists:
            // the failure must be the salvage error, not a panic
            assert!(
                !resume.status.success(),
                "resume from a never-completed save succeeded?\n{so}"
            );
            assert!(
                se.contains("no loadable checkpoint generation"),
                "expected the salvage error, got:\n{se}"
            );
            assert!(
                !se.contains("panicked"),
                "corrupt ring caused a panic:\n{se}"
            );
        } else {
            assert!(
                resume.status.success(),
                "kill@{k}: ring lost the completed save\n\
                 stdout:\n{so}\nstderr:\n{se}"
            );
            assert!(
                so.contains("resumed from step"),
                "resume did not restore a step count:\n{so}"
            );
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}

/// (c) AVX2 kernel fault injected right after the step-200 save:
/// dispatch degrades to the scalar kernels mid-run and training
/// continues. The degraded continuation must be bit-identical to a
/// forced-scalar run resumed from the same step-200 ring artifact —
/// compared via the quad-point u hash both runs print.
#[test]
#[ignore = "release-mode chaos tier (CI chaos job)"]
fn avx2_fault_degrades_bit_identical_to_scalar_continuation() {
    let dir = tmp_dir("degrade");
    let ckpt = dir.join("out.ckpt");
    let ckpt_s = ckpt.to_str().unwrap();
    // run A: fault at step 201 -> steps 201..400 run on the scalar
    // kernels; the step-200 state lands at out.ckpt.g0 after the
    // final save rotates the ring
    let a = repro(
        &[
            "train",
            "--problem", "poisson_sin",
            "--iters", "400",
            "--checkpoint", ckpt_s,
            "--checkpoint-every", "200",
            "--failpoints", "kernel.avx2.fault@201",
        ],
        &[],
    );
    let (so_a, se_a) = (stdout_of(&a), stderr_of(&a));
    assert!(a.status.success(), "run A failed:\n{so_a}\n{se_a}");
    assert!(
        se_a.contains("kernel degradation"),
        "no degradation notice on stderr:\n{se_a}"
    );
    let g0 = format!("{ckpt_s}.g0");
    assert!(
        Path::new(&g0).is_file(),
        "step-200 generation missing after the ring rotated"
    );
    // run B: resume the step-200 artifact under forced-scalar dispatch
    // and train the same 200 remaining steps
    let b = repro(
        &["train", "--resume", &g0, "--iters", "200"],
        &[("REPRO_FORCE_SCALAR", "1")],
    );
    let (so_b, se_b) = (stdout_of(&b), stderr_of(&b));
    assert!(b.status.success(), "run B failed:\n{so_b}\n{se_b}");
    assert!(
        so_b.contains("resumed from step 200"),
        "run B did not resume at step 200:\n{so_b}"
    );
    assert_eq!(
        u_hash(&so_a),
        u_hash(&so_b),
        "post-degradation trajectory is not bit-identical to the \
         scalar continuation\nrun A:\n{so_a}\nrun B:\n{so_b}"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// (e) Divergence rollback with the *persistent* worker pool: after a
/// backend diverges mid-step and rolls back to a snapshot, the pool
/// (same threads, same per-worker workspaces, same shard partials) is
/// reused for the replay. The replayed trajectory must be bit-identical
/// to a fresh backend — fresh pool, fresh workspaces, never saw the
/// NaN step — restored from the same snapshot: no stale per-worker
/// state may leak across a recovery. Runs in-process (the rollback
/// primitive is `restore_checkpoint`, no disk involved).
#[test]
#[ignore = "release-mode chaos tier (CI chaos job)"]
fn rollback_with_persistent_pool_matches_a_fresh_spawn() {
    use fastvpinns::coordinator::trainer::DataSource;
    use fastvpinns::fem::assembly;
    use fastvpinns::fem::quadrature::QuadKind;
    use fastvpinns::mesh::generators;
    use fastvpinns::problems::PoissonSin;
    use fastvpinns::runtime::backend::native::{
        NativeBackend, NativeConfig, NativeLoss,
    };
    use fastvpinns::runtime::backend::{Backend, BackendOpts};

    let mesh = generators::unit_square(8);
    let dom =
        assembly::assemble(&mesh, 5, 5, QuadKind::GaussLegendre);
    let problem = PoissonSin::new(2.0 * std::f64::consts::PI);
    let src = DataSource {
        mesh: &mesh,
        domain: Some(&dom),
        problem: &problem,
        sensor_values: None,
    };
    let ncfg = NativeConfig {
        layers: vec![2, 16, 16, 1],
        loss: NativeLoss::Forward,
        nb: 64,
        ns: 0,
    };
    let opts = BackendOpts {
        workers: Some(3),
        ..BackendOpts::default()
    };

    // backend X: train, snapshot, diverge, roll back, replay — all on
    // one pool whose threads survive the whole episode
    let mut x = NativeBackend::new(&ncfg, &src, &opts).unwrap();
    for i in 1..=30usize {
        x.step(i, 1e-3).unwrap();
    }
    let snap = x.export_checkpoint().unwrap();
    // poison the parameters and run one contaminating step: every
    // worker workspace and shard partial fills with NaN garbage
    let n = x.n_opt_params();
    x.set_params_flat(&vec![f64::NAN; n]).unwrap();
    let poisoned = x.step(31, 1e-3).unwrap();
    assert!(
        !poisoned.loss.is_finite(),
        "the poison step unexpectedly produced a finite loss"
    );
    x.restore_checkpoint(&snap).unwrap();

    // backend Y: a fresh spawn — new pool, pristine workspaces —
    // restored from the same snapshot
    let mut y = NativeBackend::new(&ncfg, &src, &opts).unwrap();
    y.restore_checkpoint(&snap).unwrap();

    // the replayed trajectories must agree bit for bit, step by step
    for i in 31..=45usize {
        let lx = x.step(i, 1e-3).unwrap().loss;
        let ly = y.step(i, 1e-3).unwrap().loss;
        assert_eq!(
            lx.to_bits(),
            ly.to_bits(),
            "step {i}: reused-pool loss {lx} != fresh-spawn loss {ly}"
        );
    }
    let px = x.params_flat();
    let py = y.params_flat();
    for (i, (a, b)) in px.iter().zip(&py).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "param {i} diverged after the replay: {a} vs {b}"
        );
    }
}

/// (f) The telemetry stream under a mid-run divergence: the recovery
/// event must land *between* the poisoned step's StepStats (loss:
/// null — emitted before the sentinel fires) and the first replayed
/// step, whose id restarts at rollback_to + 1. The interleaving is
/// read back from the stream itself, cross-checked against the
/// recovery event's own fields.
#[test]
#[ignore = "release-mode chaos tier (CI chaos job)"]
fn grad_nan_event_stream_interleaves_recovery_in_rollback_order() {
    use fastvpinns::util::json::Json;

    let dir = tmp_dir("telemetry_nan");
    let metrics = dir.join("train.jsonl");
    let metrics_s = metrics.to_str().unwrap();
    let out = repro(
        &[
            "train",
            "--problem", "poisson_sin",
            "--iters", "600",
            "--failpoints", "grad.nan@500",
            "--metrics-out", metrics_s,
        ],
        &[],
    );
    let (so, se) = (stdout_of(&out), stderr_of(&out));
    assert!(out.status.success(), "run failed:\n{so}\n{se}");

    let text = std::fs::read_to_string(&metrics).unwrap();
    std::fs::remove_dir_all(&dir).ok();
    let events: Vec<Json> = text
        .lines()
        .map(|l| Json::parse(l).expect("valid event line"))
        .collect();
    assert_eq!(
        events.last().unwrap().req("ev").unwrap().as_str().unwrap(),
        "flush",
        "clean exit must append the flush line"
    );
    let tag_at = |i: usize| {
        events[i].req("ev").unwrap().as_str().unwrap()
    };
    let recoveries: Vec<usize> = (0..events.len())
        .filter(|&i| tag_at(i) == "recovery")
        .collect();
    assert_eq!(recoveries.len(), 1, "expected exactly one recovery");
    let ri = recoveries[0];
    let rec = &events[ri];
    let at_step = rec.req("at_step").unwrap().as_usize().unwrap();
    let rollback_to =
        rec.req("rollback_to").unwrap().as_usize().unwrap();
    assert_eq!(at_step, 500, "fault was injected at step 500");
    assert!(
        rollback_to < at_step,
        "rollback_to {rollback_to} >= at_step {at_step}"
    );
    // the event immediately upstream: the poisoned step's own stats,
    // with loss nulled (NaN serializes as null by contract)
    let before = (0..ri)
        .rev()
        .find(|&i| tag_at(i) == "step")
        .expect("a step event precedes the recovery");
    let poisoned = &events[before];
    assert_eq!(
        poisoned.req("step").unwrap().as_usize().unwrap(),
        at_step,
        "recovery must directly follow the poisoned step's stats"
    );
    assert!(
        matches!(poisoned.req("loss").unwrap(), Json::Null),
        "poisoned step's loss must be null, got {poisoned}"
    );
    // and downstream: the replay resumes at rollback_to + 1
    let after = (ri + 1..events.len())
        .find(|&i| tag_at(i) == "step")
        .expect("a step event follows the recovery");
    assert_eq!(
        events[after].req("step").unwrap().as_usize().unwrap(),
        rollback_to + 1,
        "first replayed step id must be rollback_to + 1"
    );
    // the run finished its budget after healing
    let last_step = (0..events.len())
        .rev()
        .find(|&i| tag_at(i) == "step")
        .unwrap();
    assert_eq!(
        events[last_step].req("step").unwrap().as_usize().unwrap(),
        600
    );
}

/// (g) Crash (exit 137) injected mid-checkpoint with the recorder
/// armed: the metrics file must contain no torn line — every line
/// parses, the file ends at a line boundary — and no `flush` line
/// (that is the clean-shutdown marker; its absence is how a reader
/// tells a killed run from a finished one). The saves completed at
/// step 100 must have left their checkpoint events in the stream (the
/// kill fires at step 200, so the writer had 100 steps to drain them).
#[test]
#[ignore = "release-mode chaos tier (CI chaos job)"]
fn checkpoint_kill_leaves_untorn_metrics_stream() {
    use fastvpinns::util::json::Json;

    let dir = tmp_dir("telemetry_kill");
    let ckpt = dir.join("out.ckpt");
    let metrics = dir.join("train.jsonl");
    let out = repro(
        &[
            "train",
            "--problem", "poisson_sin",
            "--iters", "300",
            "--layers", "2,16,1",
            "--nb", "64",
            "--checkpoint", ckpt.to_str().unwrap(),
            "--checkpoint-every", "100",
            "--failpoints", "checkpoint.write.kill@3",
            "--metrics-out", metrics.to_str().unwrap(),
        ],
        &[],
    );
    assert_eq!(
        out.status.code(),
        Some(137),
        "kill@3 did not kill the run\nstderr:\n{}",
        stderr_of(&out)
    );
    let text = std::fs::read_to_string(&metrics).unwrap();
    std::fs::remove_dir_all(&dir).ok();
    assert!(
        text.ends_with('\n'),
        "metrics file ends mid-line after the kill"
    );
    let events: Vec<Json> = text
        .lines()
        .map(|l| {
            Json::parse(l).unwrap_or_else(|e| {
                panic!("torn/malformed line after kill: {l:?} ({e})")
            })
        })
        .collect();
    assert!(!events.is_empty(), "stream is empty");
    let tags: Vec<&str> = events
        .iter()
        .map(|e| e.req("ev").unwrap().as_str().unwrap())
        .collect();
    assert!(
        !tags.contains(&"flush"),
        "killed run must not carry the clean-shutdown flush line"
    );
    assert!(
        tags.contains(&"checkpoint"),
        "the completed first save left no checkpoint event: {tags:?}"
    );
}

/// (d) A stalled step trips the watchdog: warn-only (the run
/// completes) and counted in the report summary.
#[test]
#[ignore = "release-mode chaos tier (CI chaos job)"]
fn step_stall_trips_the_watchdog_without_killing_the_run() {
    let out = repro(
        &[
            "train",
            "--problem", "poisson_sin",
            "--iters", "10",
            "--layers", "2,8,1",
            "--nb", "32",
            "--watchdog-ms", "100",
            "--failpoints", "step.stall@3=400",
        ],
        &[],
    );
    let (so, se) = (stdout_of(&out), stderr_of(&out));
    assert!(out.status.success(), "stall killed the run:\n{so}\n{se}");
    assert!(
        se.contains("watchdog: step 3"),
        "watchdog did not flag the stalled step:\n{se}"
    );
    assert!(
        so.contains("watchdog: 1 stalled step(s) flagged"),
        "stall count missing from the summary:\n{so}"
    );
}
