//! Integration tests across runtime + coordinator + assembly, executing
//! real AOT artifacts on the PJRT client via the `XlaBackend`.
//!
//! These tests need `--features xla` plus `make artifacts`; they SKIP
//! (pass trivially with a notice) when the artifacts directory is
//! missing so that plain `cargo test --features xla` works on a fresh
//! clone. Without the xla feature the whole file compiles away — the
//! native-backend end-to-end tests live in `native_e2e.rs`.
#![cfg(feature = "xla")]

use fastvpinns::coordinator::metrics::{eval_grid, ErrorNorms};
use fastvpinns::coordinator::schedule::LrSchedule;
use fastvpinns::coordinator::trainer::{DataSource, TrainConfig, Trainer};
use fastvpinns::fem::assembly;
use fastvpinns::fem::quadrature::QuadKind;
use fastvpinns::mesh::generators;
use fastvpinns::problems::{InverseConstPoisson, PoissonSin, Problem};
use fastvpinns::runtime::backend::xla::XlaBackend;
use fastvpinns::runtime::backend::BackendOpts;
use fastvpinns::runtime::engine::Engine;

fn engine() -> Option<Engine> {
    let dir = std::path::Path::new("artifacts");
    if !dir.join("fv_poisson_ne4_nt5_nq20.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Engine::new(dir).expect("PJRT CPU client"))
}

fn trainer<'a>(
    engine: &'a Engine,
    artifact: &str,
    predict: Option<&str>,
    src: &DataSource<'_>,
    cfg: &TrainConfig,
) -> Trainer<'a> {
    let backend = XlaBackend::new(engine, artifact, predict, src,
                                  &BackendOpts::from(cfg))
        .expect("XlaBackend");
    Trainer::new(Box::new(backend), cfg)
}

#[test]
fn artifact_manifest_shapes_consistent() {
    let Some(engine) = engine() else { return };
    let art = engine.load("fv_poisson_ne4_nt5_nq20").unwrap();
    let m = &art.manifest;
    assert_eq!(m.kind, "train");
    assert_eq!(m.loss, "poisson");
    assert_eq!(m.n_param_arrays(), 8);
    let gx = &m.inputs[m.input_index("gx").unwrap()];
    assert_eq!(gx.shape, vec![4, 25, 400]);
}

#[test]
fn poisson_training_loss_decreases() {
    let Some(engine) = engine() else { return };
    let problem = PoissonSin::new(2.0 * std::f64::consts::PI);
    let mesh = generators::unit_square(2);
    let dom = assembly::assemble(&mesh, 5, 20, QuadKind::GaussLegendre);
    let src = DataSource { mesh: &mesh, domain: Some(&dom),
                           problem: &problem, sensor_values: None };
    let cfg = TrainConfig { iters: 500, ..TrainConfig::default() };
    let mut t = trainer(&engine, "fv_poisson_ne4_nt5_nq20", None, &src,
                        &cfg);
    let l0 = t.step_once().unwrap().loss;
    let report = t.run().unwrap();
    assert!(report.final_loss < 0.5 * l0,
            "loss {l0} -> {} did not halve", report.final_loss);
}

#[test]
fn training_is_deterministic_given_seed() {
    let Some(engine) = engine() else { return };
    let problem = PoissonSin::new(2.0 * std::f64::consts::PI);
    let mesh = generators::unit_square(2);
    let dom = assembly::assemble(&mesh, 5, 20, QuadKind::GaussLegendre);
    let src = DataSource { mesh: &mesh, domain: Some(&dom),
                           problem: &problem, sensor_values: None };
    let cfg = TrainConfig { iters: 30, seed: 7, ..TrainConfig::default() };
    let run = || {
        let mut t = trainer(&engine, "fv_poisson_ne4_nt5_nq20", None,
                            &src, &cfg);
        t.run().unwrap().final_loss
    };
    let a = run();
    let b = run();
    assert_eq!(a, b, "same seed must give identical trajectories");
}

#[test]
fn different_seeds_differ() {
    let Some(engine) = engine() else { return };
    let problem = PoissonSin::new(2.0 * std::f64::consts::PI);
    let mesh = generators::unit_square(2);
    let dom = assembly::assemble(&mesh, 5, 20, QuadKind::GaussLegendre);
    let src = DataSource { mesh: &mesh, domain: Some(&dom),
                           problem: &problem, sensor_values: None };
    let mut losses = vec![];
    for seed in [1u64, 2] {
        let cfg = TrainConfig { iters: 20, seed,
                                ..TrainConfig::default() };
        let mut t = trainer(&engine, "fv_poisson_ne4_nt5_nq20", None,
                            &src, &cfg);
        losses.push(t.run().unwrap().final_loss);
    }
    assert_ne!(losses[0], losses[1]);
}

#[test]
fn pinn_baseline_trains() {
    let Some(engine) = engine() else { return };
    let problem = PoissonSin::new(2.0 * std::f64::consts::PI);
    let mesh = generators::unit_square(1);
    let src = DataSource { mesh: &mesh, domain: None, problem: &problem,
                           sensor_values: None };
    let cfg = TrainConfig { iters: 100, ..TrainConfig::default() };
    let mut t = trainer(&engine, "pinn_poisson_nc400", None, &src, &cfg);
    let l0 = t.step_once().unwrap().loss;
    let report = t.run().unwrap();
    assert!(report.final_loss < l0);
}

#[test]
fn hp_loop_baseline_matches_fastvpinn_loss_at_same_params() {
    // Both compute the same mathematical objective — first-step loss at
    // identical init must agree to fp32 tolerance.
    let Some(engine) = engine() else { return };
    let problem = PoissonSin::new(2.0 * std::f64::consts::PI);
    let mesh = generators::unit_square(4);
    let dom = assembly::assemble(&mesh, 5, 5, QuadKind::GaussLegendre);
    let src = DataSource { mesh: &mesh, domain: Some(&dom),
                           problem: &problem, sensor_values: None };
    let cfg = TrainConfig { iters: 1, seed: 11, ..TrainConfig::default() };
    let mut fv = trainer(&engine, "fv_poisson_ne16_nt5_nq5", None, &src,
                         &cfg);
    let mut hp = trainer(&engine, "hp_poisson_ne16_nt5_nq5", None, &src,
                         &cfg);
    let lf = fv.step_once().unwrap().loss;
    let lh = hp.step_once().unwrap().loss;
    let rel = (lf - lh).abs() / lf.abs().max(1e-12);
    assert!(rel < 1e-3, "fv {lf} vs hp {lh} (rel {rel})");
}

#[test]
fn predict_pads_and_chunks() {
    let Some(engine) = engine() else { return };
    let problem = PoissonSin::new(2.0 * std::f64::consts::PI);
    let mesh = generators::unit_square(2);
    let dom = assembly::assemble(&mesh, 5, 20, QuadKind::GaussLegendre);
    let src = DataSource { mesh: &mesh, domain: Some(&dom),
                           problem: &problem, sensor_values: None };
    let cfg = TrainConfig { iters: 1, ..TrainConfig::default() };
    let t = trainer(&engine, "fv_poisson_ne4_nt5_nq20",
                    Some("predict_std_16k"), &src, &cfg);
    // 3 points (heavy padding) and 20,000 points (chunking)
    let small = t.predict(&[[0.1, 0.1], [0.5, 0.5], [0.9, 0.9]]).unwrap();
    assert_eq!(small.len(), 3);
    let many: Vec<[f64; 2]> = (0..20_000)
        .map(|i| [(i % 141) as f64 / 141.0, (i % 89) as f64 / 89.0])
        .collect();
    let big = t.predict(&many).unwrap();
    assert_eq!(big.len(), 20_000);
    // consistency: same point -> same value in both calls
    let p0 = t.predict(&[[0.5, 0.5]]).unwrap()[0];
    let again = t.predict(&[[0.5, 0.5]]).unwrap()[0];
    assert_eq!(p0, again);
}

#[test]
fn inverse_const_eps_moves_toward_target() {
    let Some(engine) = engine() else { return };
    let problem = InverseConstPoisson::new();
    let mesh = generators::rect_grid(2, 2, -1.0, -1.0, 1.0, 1.0);
    let dom = assembly::assemble(&mesh, 5, 40, QuadKind::GaussLegendre);
    let src = DataSource { mesh: &mesh, domain: Some(&dom),
                           problem: &problem, sensor_values: None };
    let cfg = TrainConfig {
        iters: 600,
        lr: LrSchedule::Constant(5e-3),
        eps_init: 2.0,
        ..TrainConfig::default()
    };
    let mut t = trainer(&engine, "fv_inverse_const_ne4_nt5_nq40", None,
                        &src, &cfg);
    let eps0 = t.current_eps().unwrap();
    assert!((eps0 - 2.0).abs() < 1e-6);
    let report = t.run().unwrap();
    let eps = report.eps_final.unwrap();
    // after 600 steps eps must have moved substantially off its init
    assert!((eps - 2.0).abs() > 0.05, "eps stuck at {eps}");
    assert!(report.final_loss.is_finite());
}

#[test]
fn trained_model_beats_untrained_on_error_norms() {
    let Some(engine) = engine() else { return };
    let problem = PoissonSin::new(2.0 * std::f64::consts::PI);
    let mesh = generators::unit_square(2);
    let dom = assembly::assemble(&mesh, 5, 20, QuadKind::GaussLegendre);
    let src = DataSource { mesh: &mesh, domain: Some(&dom),
                           problem: &problem, sensor_values: None };
    let grid = eval_grid(50, 50, 0.0, 0.0, 1.0, 1.0);
    let exact: Vec<f64> = grid
        .iter()
        .map(|p| problem.exact(p[0], p[1]).unwrap())
        .collect();
    let err_at = |iters: usize| -> ErrorNorms {
        let cfg = TrainConfig { iters, ..TrainConfig::default() };
        let mut t = trainer(&engine, "fv_poisson_ne4_nt5_nq20",
                            Some("predict_std_16k"), &src, &cfg);
        t.run().unwrap();
        t.evaluate(&grid, &exact).unwrap()
    };
    let early = err_at(5);
    let late = err_at(800);
    assert!(late.mae < early.mae,
            "training made things worse: {} -> {}", early.mae, late.mae);
}

#[test]
fn gear_artifact_loads_and_steps() {
    let Some(engine) = engine() else { return };
    let art = engine.load("fv_cd_gear").unwrap();
    let c = &art.manifest.config;
    let mesh = generators::gear_ci();
    assert_eq!(mesh.n_cells(), c.ne,
               "gear generator and artifact disagree on NE");
    let problem = fastvpinns::problems::GearCd;
    let dom = assembly::assemble(&mesh, c.nt1d, c.nq1d,
                                 QuadKind::GaussLegendre);
    let src = DataSource { mesh: &mesh, domain: Some(&dom),
                           problem: &problem, sensor_values: None };
    let cfg = TrainConfig { iters: 3, ..TrainConfig::default() };
    let mut t = trainer(&engine, "fv_cd_gear", None, &src, &cfg);
    let report = t.run().unwrap();
    assert!(report.final_loss.is_finite());
}
