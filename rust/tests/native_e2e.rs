//! End-to-end tests of the native pure-Rust backend: training smoke
//! (loss must drop >= 10x in 500 iters) for Poisson and the
//! generalized-form scenarios (Helmholtz reaction term, hoisted
//! variable-convection tables), FEM cross-validation of trained
//! networks, the inverse tier (scalar-eps recovery to paper accuracy
//! and the two-head eps-field smoke) and the helmholtz/cd_var
//! convergence tier — both tiers `#[ignore]`d in the debug-mode
//! default suite; the CI release-tier job runs them in release via
//! name filters + `--include-ignored` — and backend/coordinator
//! integration. No artifacts, no XLA.

use fastvpinns::coordinator::metrics::{eval_grid, ErrorNorms};
use fastvpinns::coordinator::schedule::LrSchedule;
use fastvpinns::coordinator::trainer::{DataSource, TrainConfig, Trainer};
use fastvpinns::fem::assembly;
use fastvpinns::fem::quadrature::QuadKind;
use fastvpinns::fem_solver::{self, FemProblem};
use fastvpinns::mesh::generators;
use fastvpinns::problems::{
    Helmholtz2D, InverseConstPoisson, InverseSpaceSin, PoissonSin,
    Problem, VariableConvectionCd,
};
use fastvpinns::runtime::backend::native::{
    NativeBackend, NativeConfig, NativeLoss,
};
use fastvpinns::runtime::backend::BackendOpts;

/// Standard small poisson_sin(pi) setup: 2x2 elements, 3^2 tests, 8^2
/// quad, 16x2 net — converges fast enough for debug-mode CI.
fn poisson_trainer<'a>(
    mesh: &'a fastvpinns::mesh::QuadMesh,
    dom: &'a fastvpinns::fem::assembly::AssembledDomain,
    problem: &'a PoissonSin,
    cfg: &TrainConfig,
) -> Trainer<'a> {
    let src = DataSource {
        mesh,
        domain: Some(dom),
        problem,
        sensor_values: None,
    };
    let ncfg = NativeConfig {
        layers: vec![2, 16, 16, 1],
        loss: NativeLoss::Forward,
        nb: 80,
        ns: 0,
    };
    let backend =
        NativeBackend::new(&ncfg, &src, &BackendOpts::from(cfg)).unwrap();
    Trainer::new(Box::new(backend), cfg)
}

#[test]
fn poisson_sin_smoke_loss_drops_10x_in_500_iters() {
    let problem = PoissonSin::new(std::f64::consts::PI);
    let mesh = generators::unit_square(2);
    let dom = assembly::assemble(&mesh, 3, 8, QuadKind::GaussLegendre);
    let cfg = TrainConfig {
        iters: 500,
        lr: LrSchedule::Constant(1e-2),
        ..TrainConfig::default()
    };
    let mut t = poisson_trainer(&mesh, &dom, &problem, &cfg);
    let l0 = t.step_once().unwrap().loss;
    let report = t.run().unwrap();
    assert!(
        report.final_loss < 0.1 * l0,
        "loss {l0:.3e} -> {:.3e}: less than 10x decrease in 500 iters",
        report.final_loss
    );
}

#[test]
fn trained_network_cross_validates_against_fem() {
    // Train the native backend, then compare its field against the
    // classical FEM solver — two completely independent discretizations
    // of the same PDE must agree.
    let problem = PoissonSin::new(std::f64::consts::PI);
    let mesh = generators::unit_square(2);
    let dom = assembly::assemble(&mesh, 3, 6, QuadKind::GaussLegendre);
    let cfg = TrainConfig {
        iters: 1500,
        lr: LrSchedule::Constant(1e-2),
        log_every: 100,
        ..TrainConfig::default()
    };
    let mut t = poisson_trainer(&mesh, &dom, &problem, &cfg);
    t.run().unwrap();

    // FEM reference on a finer grid of the same domain
    let fem_mesh = generators::unit_square(16);
    let om = problem.omega;
    let fem = fem_solver::solve(
        &fem_mesh,
        &FemProblem {
            eps: &|_, _| 1.0,
            b: None,
            c: None,
            // forcing matches problems::PoissonSin (exact u = -sin sin)
            f: &|x, y| -2.0 * om * om * (om * x).sin() * (om * y).sin(),
            g: &|_, _| 0.0,
        },
        3,
    )
    .unwrap();

    let pred = t.predict(&fem_mesh.points).unwrap();
    let nn_vs_fem = ErrorNorms::compute_f32(&pred, fem.nodal()).unwrap();
    assert!(
        nn_vs_fem.rel_l2 < 0.08,
        "NN vs FEM rel-L2 {} (MAE {})", nn_vs_fem.rel_l2, nn_vs_fem.mae
    );

    // and both must be close to the analytic solution
    let exact: Vec<f64> = fem_mesh
        .points
        .iter()
        .map(|p| problem.exact(p[0], p[1]).unwrap())
        .collect();
    let nn_err = ErrorNorms::compute_f32(&pred, &exact).unwrap();
    let fem_err = ErrorNorms::compute(fem.nodal(), &exact).unwrap();
    assert!(nn_err.rel_l2 < 0.05, "NN rel-L2 vs exact {}", nn_err.rel_l2);
    assert!(fem_err.rel_l2 < 0.05, "FEM rel-L2 vs exact {}",
            fem_err.rel_l2);
}

#[test]
fn native_training_is_deterministic_given_seed() {
    let problem = PoissonSin::new(std::f64::consts::PI);
    let mesh = generators::unit_square(2);
    let dom = assembly::assemble(&mesh, 3, 6, QuadKind::GaussLegendre);
    let cfg = TrainConfig { iters: 40, seed: 9, ..TrainConfig::default() };
    let run = || {
        let mut t = poisson_trainer(&mesh, &dom, &problem, &cfg);
        t.run().unwrap().final_loss
    };
    assert_eq!(run(), run(), "same seed must give identical trajectories");
}

#[test]
fn native_seeds_differ() {
    let problem = PoissonSin::new(std::f64::consts::PI);
    let mesh = generators::unit_square(2);
    let dom = assembly::assemble(&mesh, 3, 6, QuadKind::GaussLegendre);
    let loss_for = |seed: u64| {
        let cfg = TrainConfig { iters: 20, seed,
                                ..TrainConfig::default() };
        let mut t = poisson_trainer(&mesh, &dom, &problem, &cfg);
        t.run().unwrap().final_loss
    };
    assert_ne!(loss_for(1), loss_for(2));
}

#[test]
fn native_inverse_eps_moves_toward_target() {
    // CI-scale fig14: eps starts at 2.0 and must move toward 0.3.
    let problem = InverseConstPoisson::new();
    let mesh = generators::rect_grid(2, 2, -1.0, -1.0, 1.0, 1.0);
    let dom = assembly::assemble(&mesh, 3, 10, QuadKind::GaussLegendre);
    let src = DataSource { mesh: &mesh, domain: Some(&dom),
                           problem: &problem, sensor_values: None };
    let cfg = TrainConfig {
        iters: 300,
        lr: LrSchedule::Constant(5e-3),
        eps_init: 2.0,
        ..TrainConfig::default()
    };
    let ncfg = NativeConfig {
        layers: vec![2, 16, 16, 1],
        loss: NativeLoss::InverseConst,
        nb: 80,
        ns: 20,
    };
    let backend =
        NativeBackend::new(&ncfg, &src, &BackendOpts::from(&cfg)).unwrap();
    let mut t = Trainer::new(Box::new(backend), &cfg);
    let eps0 = t.current_eps().unwrap();
    assert!((eps0 - 2.0).abs() < 1e-12);
    let report = t.run().unwrap();
    let eps = report.eps_final.unwrap();
    assert!(report.final_loss.is_finite());
    assert!((eps - 2.0).abs() > 0.05, "eps stuck at {eps}");
    assert!(eps < 2.0, "eps should decrease toward 0.3, got {eps}");
}

#[test]
#[ignore = "release inverse tier (CI: --include-ignored); slow in debug"]
fn inverse_const_recovers_eps_to_paper_accuracy() {
    // Paper SS4.7.1 at CI scale: starting from eps = 2.0, the scalar
    // diffusion coefficient must recover eps_actual = 0.3 to within
    // 1e-2 inside a bounded iteration budget (numpy transliteration:
    // first |eps - 0.3| < 1e-2 hit between ~230 and ~1700 iters across
    // seeds; 4000 gives >2x headroom). Early-stops once well inside.
    let problem = InverseConstPoisson::new();
    let mesh = generators::rect_grid(2, 2, -1.0, -1.0, 1.0, 1.0);
    let dom = assembly::assemble(&mesh, 3, 10, QuadKind::GaussLegendre);
    let src = DataSource { mesh: &mesh, domain: Some(&dom),
                           problem: &problem, sensor_values: None };
    let cfg = TrainConfig {
        iters: 4000,
        lr: LrSchedule::Constant(5e-3),
        eps_init: 2.0,
        eps_converge: Some((0.3, 5e-3)),
        log_every: 200,
        ..TrainConfig::default()
    };
    let ncfg = NativeConfig {
        layers: vec![2, 16, 16, 1],
        loss: NativeLoss::InverseConst,
        nb: 80,
        ns: 20,
    };
    let backend =
        NativeBackend::new(&ncfg, &src, &BackendOpts::from(&cfg)).unwrap();
    let mut t = Trainer::new(Box::new(backend), &cfg);
    let report = t.run().unwrap();
    let eps = report.eps_final.unwrap();
    assert!(
        (eps - 0.3).abs() < 1e-2,
        "eps = {eps} after {} iters (converged_early = {}): \
         |eps - 0.3| >= 1e-2",
        report.steps, report.converged_early
    );
}

#[test]
#[ignore = "release inverse tier (CI: --include-ignored); slow in debug"]
fn inverse_space_smoke_recovers_eps_field_2x() {
    // Two-head inverse-space smoke on a 4-element mesh: training must
    // reduce ||eps - eps*||_L2 on an interior grid by >= 2x from the
    // softplus init (numpy transliteration reaches 4-13x at this
    // budget across seeds; 2x is the floor).
    let problem = InverseSpaceSin;
    let mesh = generators::unit_square(2);
    let dom = assembly::assemble(&mesh, 3, 8, QuadKind::GaussLegendre);
    let src = DataSource { mesh: &mesh, domain: Some(&dom),
                           problem: &problem, sensor_values: None };
    let cfg = TrainConfig {
        iters: 2000,
        lr: LrSchedule::Constant(5e-3),
        log_every: 200,
        ..TrainConfig::default()
    };
    let ncfg = NativeConfig {
        layers: vec![2, 16, 16, 1],
        loss: NativeLoss::InverseSpace,
        nb: 80,
        ns: 60,
    };
    let backend =
        NativeBackend::new(&ncfg, &src, &BackendOpts::from(&cfg)).unwrap();
    let mut t = Trainer::new(Box::new(backend), &cfg);

    let grid = eval_grid(30, 30, 0.02, 0.02, 0.98, 0.98);
    let eps_exact: Vec<f64> = grid
        .iter()
        .map(|p| InverseSpaceSin::eps_actual(p[0], p[1]))
        .collect();
    let eps_l2 = |t: &Trainer| -> f64 {
        let pred = t.predict_eps_field(&grid).unwrap();
        let sq: f64 = pred
            .iter()
            .zip(&eps_exact)
            .map(|(&p, &r)| (p as f64 - r) * (p as f64 - r))
            .sum();
        (sq / grid.len() as f64).sqrt()
    };
    let e0 = eps_l2(&t);
    let report = t.run().unwrap();
    let e1 = eps_l2(&t);
    assert!(report.final_loss.is_finite());
    assert!(
        2.0 * e1 <= e0,
        "||eps - eps*|| {e0:.4} -> {e1:.4}: less than 2x reduction in \
         {} iters", report.steps
    );
    // and u itself must have learned something
    let exact: Vec<f64> = grid
        .iter()
        .map(|p| problem.exact(p[0], p[1]).unwrap())
        .collect();
    let err = t.evaluate(&grid, &exact).unwrap();
    assert!(err.rel_l2 < 0.2, "u rel-L2 {} after training", err.rel_l2);
}

#[test]
fn helmholtz_smoke_loss_drops_10x_in_500_iters() {
    // the reaction term (c = -k^2) rides the same tensor contraction:
    // the generalized path must train Helmholtz as readily as Poisson
    let problem = Helmholtz2D::new(std::f64::consts::PI);
    let mesh = generators::unit_square(2);
    let dom = assembly::assemble(&mesh, 3, 8, QuadKind::GaussLegendre);
    let src = DataSource { mesh: &mesh, domain: Some(&dom),
                           problem: &problem, sensor_values: None };
    let cfg = TrainConfig {
        iters: 500,
        lr: LrSchedule::Constant(1e-2),
        ..TrainConfig::default()
    };
    let ncfg = NativeConfig {
        layers: vec![2, 16, 16, 1],
        loss: NativeLoss::Forward,
        nb: 80,
        ns: 0,
    };
    let backend =
        NativeBackend::new(&ncfg, &src, &BackendOpts::from(&cfg)).unwrap();
    let mut t = Trainer::new(Box::new(backend), &cfg);
    assert_eq!(t.loss_kind(), "helmholtz");
    let l0 = t.step_once().unwrap().loss;
    let report = t.run().unwrap();
    assert!(
        report.final_loss < 0.1 * l0,
        "helmholtz loss {l0:.3e} -> {:.3e}: < 10x drop in 500 iters",
        report.final_loss
    );
}

#[test]
fn cd_var_smoke_loss_drops_10x_in_500_iters() {
    // hoisted per-point convection tables through the same kernel
    let problem = VariableConvectionCd::new();
    let mesh = generators::unit_square(2);
    let dom = assembly::assemble(&mesh, 3, 8, QuadKind::GaussLegendre);
    let src = DataSource { mesh: &mesh, domain: Some(&dom),
                           problem: &problem, sensor_values: None };
    let cfg = TrainConfig {
        iters: 500,
        lr: LrSchedule::Constant(1e-2),
        ..TrainConfig::default()
    };
    let ncfg = NativeConfig {
        layers: vec![2, 16, 16, 1],
        loss: NativeLoss::Forward,
        nb: 80,
        ns: 0,
    };
    let backend =
        NativeBackend::new(&ncfg, &src, &BackendOpts::from(&cfg)).unwrap();
    let mut t = Trainer::new(Box::new(backend), &cfg);
    assert_eq!(t.loss_kind(), "cd");
    let l0 = t.step_once().unwrap().loss;
    let report = t.run().unwrap();
    assert!(
        report.final_loss < 0.1 * l0,
        "cd_var loss {l0:.3e} -> {:.3e}: < 10x drop in 500 iters",
        report.final_loss
    );
}

#[test]
#[ignore = "release helmholtz tier (CI: --include-ignored); slow in debug"]
fn helmholtz_converges_and_cross_validates_against_fem() {
    // Release-tier Helmholtz e2e at CI scale (2x2 mesh, 16x2 net):
    // the decayed-lr budget reaches rel-L2 ~0.8e-2..2.6e-2 across
    // seeds in the numpy transliteration, so 5e-2 is the floor with
    // ~2x headroom; the strict 1e-2 acceptance bar applies to the
    // CLI-default run (30x3 net, coarse 2x2 mesh, decayed lr — see
    // problems::registry) exercised separately by the release CI job.
    let problem = Helmholtz2D::new(std::f64::consts::PI);
    let mesh = generators::unit_square(2);
    let dom = assembly::assemble(&mesh, 3, 8, QuadKind::GaussLegendre);
    let src = DataSource { mesh: &mesh, domain: Some(&dom),
                           problem: &problem, sensor_values: None };
    let cfg = TrainConfig {
        iters: 3000,
        lr: LrSchedule::ExpDecay { lr0: 1e-2, factor: 0.5, every: 500 },
        log_every: 200,
        ..TrainConfig::default()
    };
    let ncfg = NativeConfig {
        layers: vec![2, 16, 16, 1],
        loss: NativeLoss::Forward,
        nb: 80,
        ns: 0,
    };
    let backend =
        NativeBackend::new(&ncfg, &src, &BackendOpts::from(&cfg)).unwrap();
    let mut t = Trainer::new(Box::new(backend), &cfg);
    t.run().unwrap();

    let grid = eval_grid(50, 50, 0.0, 0.0, 1.0, 1.0);
    let exact: Vec<f64> = grid
        .iter()
        .map(|p| problem.exact(p[0], p[1]).unwrap())
        .collect();
    let err = t.evaluate(&grid, &exact).unwrap();
    assert!(err.rel_l2 < 5e-2,
            "helmholtz rel-L2 {} >= 5e-2 vs exact", err.rel_l2);

    // independent discretization must agree with the trained network
    let fem_mesh = generators::unit_square(16);
    let fem = fem_solver::solve_problem(&fem_mesh, &problem, 3).unwrap();
    let pred = t.predict(&fem_mesh.points).unwrap();
    let nn_vs_fem = ErrorNorms::compute_f32(&pred, fem.nodal()).unwrap();
    assert!(nn_vs_fem.rel_l2 < 0.05,
            "helmholtz NN vs FEM rel-L2 {}", nn_vs_fem.rel_l2);
}

#[test]
#[ignore = "release helmholtz tier (CI: --include-ignored); slow in debug"]
fn cd_var_converges_and_cross_validates_against_fem() {
    // Release-tier variable-convection e2e: the hoisted b(x,y) tables
    // must train to the manufactured solution and agree with the FEM
    // reference that assembles the same rotating field (numpy
    // transliteration: rel-L2 ~0.8e-2..1.3e-2 across seeds at this
    // decayed-lr budget; 5e-2 is the floor).
    let problem = VariableConvectionCd::new();
    let mesh = generators::unit_square(2);
    let dom = assembly::assemble(&mesh, 3, 8, QuadKind::GaussLegendre);
    let src = DataSource { mesh: &mesh, domain: Some(&dom),
                           problem: &problem, sensor_values: None };
    let cfg = TrainConfig {
        iters: 3000,
        lr: LrSchedule::ExpDecay { lr0: 1e-2, factor: 0.5, every: 500 },
        log_every: 200,
        ..TrainConfig::default()
    };
    let ncfg = NativeConfig {
        layers: vec![2, 16, 16, 1],
        loss: NativeLoss::Forward,
        nb: 80,
        ns: 0,
    };
    let backend =
        NativeBackend::new(&ncfg, &src, &BackendOpts::from(&cfg)).unwrap();
    let mut t = Trainer::new(Box::new(backend), &cfg);
    t.run().unwrap();

    let grid = eval_grid(50, 50, 0.0, 0.0, 1.0, 1.0);
    let exact: Vec<f64> = grid
        .iter()
        .map(|p| problem.exact(p[0], p[1]).unwrap())
        .collect();
    let err = t.evaluate(&grid, &exact).unwrap();
    assert!(err.rel_l2 < 5e-2,
            "cd_var rel-L2 {} >= 5e-2 vs exact", err.rel_l2);

    let fem_mesh = generators::unit_square(16);
    let fem = fem_solver::solve_problem(&fem_mesh, &problem, 3).unwrap();
    let pred = t.predict(&fem_mesh.points).unwrap();
    let nn_vs_fem = ErrorNorms::compute_f32(&pred, fem.nodal()).unwrap();
    assert!(nn_vs_fem.rel_l2 < 0.05,
            "cd_var NN vs FEM rel-L2 {}", nn_vs_fem.rel_l2);
}

#[test]
fn trained_model_beats_untrained_on_error_norms() {
    let problem = PoissonSin::new(std::f64::consts::PI);
    let mesh = generators::unit_square(2);
    let dom = assembly::assemble(&mesh, 3, 8, QuadKind::GaussLegendre);
    let grid = eval_grid(40, 40, 0.0, 0.0, 1.0, 1.0);
    let exact: Vec<f64> = grid
        .iter()
        .map(|p| problem.exact(p[0], p[1]).unwrap())
        .collect();
    let err_at = |iters: usize| {
        let cfg = TrainConfig {
            iters,
            lr: LrSchedule::Constant(1e-2),
            ..TrainConfig::default()
        };
        let mut t = poisson_trainer(&mesh, &dom, &problem, &cfg);
        t.run().unwrap();
        t.evaluate(&grid, &exact).unwrap()
    };
    let early = err_at(5);
    let late = err_at(600);
    assert!(late.mae < early.mae,
            "training made things worse: {} -> {}", early.mae, late.mae);
}
