//! End-to-end serve tests: an in-process server exercised over real
//! TCP sockets, proving the tentpole claims — concurrent micro-batched
//! clients get answers bit-identical to a lone single-threaded
//! [`InferenceSession`], a checkpoint-load fault is an error reply
//! plus an eviction (never a dead server), and shutdown drains
//! gracefully. The SIGTERM scenario spawns the real `repro serve`
//! binary (release-tier, `#[ignore]`d like the chaos suite).

use std::path::{Path, PathBuf};
use std::time::Duration;

use fastvpinns::runtime::failpoint;
use fastvpinns::runtime::infer::{InferenceSession, Precision};
use fastvpinns::serve::bench::synthetic_checkpoint;
use fastvpinns::serve::{
    BatchPolicy, ServeClient, ServeConfig, Server,
};

fn tmp_registry(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "fastvpinns_serve_e2e_{tag}_{}",
        std::process::id()
    ));
    std::fs::remove_dir_all(&dir).ok();
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn write_model(
    dir: &Path,
    name: &str,
    layers: &[usize],
    two_head: bool,
    seed: u64,
) {
    let ck = synthetic_checkpoint(layers, two_head, seed).unwrap();
    ck.write(dir.join(format!("{name}.ckpt"))).unwrap();
}

/// Deterministic query cloud for one (client, request) pair.
fn query(client: usize, req: usize, n: usize) -> Vec<[f64; 2]> {
    let salt = 0.23 * client as f64 + 0.041 * req as f64;
    (0..n)
        .map(|i| {
            let t = (i as f64 + 0.5) / n as f64;
            [(t + salt).fract(), (t * 1.618 + salt).fract()]
        })
        .collect()
}

/// The whole in-process serve lifecycle in one sequential test: the
/// failpoint table is process-global state, so the scenarios must not
/// interleave with each other.
#[test]
fn serve_e2e_lifecycle() {
    let dir = tmp_registry("lifecycle");
    write_model(&dir, "fwd", &[2, 10, 10, 1], false, 11);
    write_model(&dir, "twohead", &[2, 8, 1], true, 12);
    write_model(&dir, "lazy", &[2, 6, 1], false, 13);

    let mut config = ServeConfig::new("127.0.0.1:0", &dir);
    config.workers_per_model = 3;
    config.policy = BatchPolicy {
        max_batch: 4,
        max_wait: Duration::from_millis(5),
        queue_depth: 32,
    };
    let handle = Server::spawn(config).unwrap();
    let addr = handle.addr();

    // --- liveness + registry listing -------------------------------
    let mut probe = ServeClient::connect(addr).unwrap();
    probe.ping().unwrap();
    assert_eq!(probe.models().unwrap(), ["fwd", "lazy", "twohead"]);

    // --- concurrent clients vs lone sessions, bit for bit ----------
    let mut lone_fwd = InferenceSession::open(dir.join("fwd.ckpt"))
        .unwrap();
    let mut lone_two =
        InferenceSession::open(dir.join("twohead.ckpt")).unwrap();
    const CLIENTS: usize = 6;
    const REQS: usize = 8;
    // expected outputs computed single-threaded, before any traffic
    let mut want = Vec::new();
    for c in 0..CLIENTS {
        let mut per_client = Vec::new();
        for r in 0..REQS {
            let q = query(c, r, 16 + (c + r) % 5);
            let out = if r % 2 == 0 {
                lone_fwd.eval(&q)
            } else {
                lone_two.eval(&q)
            };
            per_client.push((q, out));
        }
        want.push(per_client);
    }
    let joins: Vec<_> = want
        .iter()
        .cloned()
        .enumerate()
        .map(|(c, per_client)| {
            std::thread::spawn(move || {
                let mut client =
                    ServeClient::connect(addr).unwrap();
                for (r, (q, (want_u, want_eps))) in
                    per_client.into_iter().enumerate()
                {
                    let model =
                        if r % 2 == 0 { "fwd" } else { "twohead" };
                    let (u, eps) =
                        client.eval(model, &q, None).unwrap();
                    assert_eq!(u, want_u, "client {c} req {r}");
                    assert_eq!(eps, want_eps, "client {c} req {r}");
                }
            })
        })
        .collect();
    for j in joins {
        j.join().unwrap();
    }

    // --- the f32 path is the lone session's f32 path, bit for bit --
    lone_fwd.set_precision(Precision::F32);
    let q = query(0, 99, 32);
    let want_f32 = lone_fwd.eval(&q);
    let got_f32 = probe
        .eval("fwd", &q, Some(Precision::F32))
        .unwrap();
    assert_eq!(got_f32.0, want_f32.0);
    assert!(got_f32.1.is_none());

    // --- stats: counted, finite, with batch + latency fields -------
    let stats = probe.stats().unwrap();
    let requests =
        stats.req("requests").unwrap().as_usize().unwrap();
    assert!(
        requests >= CLIENTS * REQS,
        "only {requests} requests recorded"
    );
    let lat = stats.req("latency_ms").unwrap();
    for field in ["p50", "p90", "p99", "max", "mean"] {
        let v = lat.req(field).unwrap().as_f64().unwrap();
        assert!(v.is_finite() && v >= 0.0, "{field} = {v}");
    }
    assert_eq!(lat.req("dropped").unwrap().as_usize().unwrap(), 0);
    let batch = stats.req("batch").unwrap();
    let fill = batch.req("fill").unwrap().as_f64().unwrap();
    assert!(fill > 0.0 && fill <= 1.0, "fill {fill}");
    assert_eq!(
        batch.req("max_batch").unwrap().as_usize().unwrap(),
        4
    );
    // the traffic above went through the pool queues: the high-water
    // mark saw at least one job, and the backlog fully drained
    let hwm = batch.req("queue_hwm").unwrap().as_usize().unwrap();
    assert!(hwm >= 1, "queue_hwm {hwm}");
    assert_eq!(batch.req("queued").unwrap().as_usize().unwrap(), 0);
    let hits = stats.req("models").unwrap();
    assert!(hits.req("fwd").unwrap().as_usize().unwrap() > 0);
    assert!(hits.req("twohead").unwrap().as_usize().unwrap() > 0);

    // --- a bad request is an error reply, not a dead connection ----
    let err = probe
        .eval("no_such_model", &q, None)
        .unwrap_err()
        .to_string();
    assert!(err.contains("no_such_model"), "{err}");
    probe.ping().unwrap(); // same connection still serves

    // --- io.read.err mid-load: error reply + eviction, then heal ---
    // "lazy" has never been queried, so the next eval must read the
    // artifact; the armed failpoint makes that read fail exactly once.
    failpoint::arm_from_spec("io.read.err@1").unwrap();
    let err = probe
        .eval("lazy", &q, None)
        .unwrap_err()
        .to_string();
    assert!(err.contains("lazy"), "{err}");
    assert_eq!(failpoint::fired_count("io.read.err"), 1);
    // the server survived, nothing broken was cached, and the very
    // next request loads the model cleanly
    let healed = probe.eval("lazy", &q, None).unwrap();
    assert_eq!(healed.0.len(), q.len());
    failpoint::disarm_all();

    // --- graceful shutdown via the protocol ------------------------
    let before = handle.stats();
    probe.shutdown_server().unwrap();
    handle.shutdown().unwrap();
    assert!(before.requests() > 0);
    // the listener is gone: fresh connections are refused
    assert!(ServeClient::connect(addr).is_err());

    std::fs::remove_dir_all(&dir).ok();
}

/// SIGTERM against the real `repro serve` binary: the process must
/// drain and exit 0, printing its final stats — the CI `serve-smoke`
/// scenario in miniature. Release tier (`--include-ignored`).
#[cfg(unix)]
#[test]
#[ignore = "spawns the release binary (CI serve-smoke job)"]
fn sigterm_drains_the_serve_binary() {
    use std::io::{BufRead, BufReader, Read};
    use std::process::{Command, Stdio};

    let dir = tmp_registry("sigterm");
    write_model(&dir, "m", &[2, 8, 1], false, 5);

    let mut child = Command::new(env!("CARGO_BIN_EXE_repro"))
        .args([
            "serve",
            "--registry",
            dir.to_str().unwrap(),
            "--addr",
            "127.0.0.1:0",
        ])
        .env("FASTVPINNS_THREADS", "2")
        .stdout(Stdio::piped())
        .spawn()
        .expect("spawn repro serve");
    let mut stdout =
        BufReader::new(child.stdout.take().expect("child stdout"));
    let mut line = String::new();
    stdout.read_line(&mut line).unwrap();
    assert!(line.contains("listening on"), "{line}");
    let addr = line
        .split("listening on ")
        .nth(1)
        .and_then(|rest| rest.split_whitespace().next())
        .unwrap_or_else(|| panic!("no address in {line:?}"))
        .to_string();

    // real traffic through the spawned server
    let mut client = ServeClient::connect(&*addr).unwrap();
    client.ping().unwrap();
    let (u, _) = client.eval("m", &query(0, 0, 64), None).unwrap();
    assert_eq!(u.len(), 64);

    // SIGTERM mid-flight: the server must drain, not die
    let status = Command::new("kill")
        .args(["-TERM", &child.id().to_string()])
        .status()
        .expect("send SIGTERM");
    assert!(status.success());
    let exit = child.wait().expect("wait for drain");
    assert!(exit.success(), "serve exited {exit:?}");
    let mut rest = String::new();
    stdout.read_to_string(&mut rest).unwrap();
    assert!(rest.contains("drained"), "missing drain line:\n{rest}");
    assert!(rest.contains("requests"), "missing final stats:\n{rest}");

    std::fs::remove_dir_all(&dir).ok();
}
