//! Offline stub of the `xla` (xla-rs) API surface used by fastvpinns.
//!
//! The host-side pieces (`Literal`, shape handling) are real
//! implementations so `TensorData` round-trips and unit tests work
//! without PJRT. The device-side pieces (`PjRtClient::compile`,
//! executable execution) return an explanatory `Error`: actually running
//! AOT artifacts requires replacing this path dependency with the real
//! `xla` crate in an environment that has the PJRT CPU plugin.

use std::fmt;
use std::path::Path;

/// Stub error type mirroring xla-rs's error enum surface.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla stub: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what} is unavailable in the offline stub — build against the \
         real `xla` crate (see rust/Cargo.toml) to execute AOT artifacts"
    )))
}

/// Array shape (dims in elements, f32 only in this stub).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// A host-resident tensor literal (f32, C order).
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    dims: Vec<i64>,
    data: Vec<f32>,
}

impl Literal {
    pub fn scalar(v: f32) -> Literal {
        Literal { dims: vec![], data: vec![v] }
    }

    pub fn vec1(data: &[f32]) -> Literal {
        Literal { dims: vec![data.len() as i64], data: data.to_vec() }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.data.len() {
            return Err(Error(format!(
                "reshape to {dims:?} wants {n} elements, literal has {}",
                self.data.len()
            )));
        }
        Ok(Literal { dims: dims.to_vec(), data: self.data.clone() })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape { dims: self.dims.clone() })
    }

    pub fn to_vec<T: FromF32>(&self) -> Result<Vec<T>> {
        Ok(self.data.iter().map(|&v| T::from_f32(v)).collect())
    }

    pub fn size_bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }

    /// The stub never materialises tuple literals (they only come out of
    /// executable runs, which the stub cannot perform).
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable("tuple literal decomposition")
    }
}

/// Element conversion helper for `Literal::to_vec` (f32-only stub).
pub trait FromF32 {
    fn from_f32(v: f32) -> Self;
}

impl FromF32 for f32 {
    fn from_f32(v: f32) -> f32 {
        v
    }
}

/// A device buffer. In the stub it simply pins the source literal.
#[derive(Debug, Clone)]
pub struct PjRtBuffer {
    literal: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.literal.clone())
    }
}

/// Parsed HLO module. The stub only checks the file exists and keeps the
/// text so `compile` can report a useful message.
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    _text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| Error(format!("read {}: {e}",
                                       path.as_ref().display())))?;
        Ok(HloModuleProto { _text: text })
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Debug, Clone)]
pub struct XlaComputation {
    _proto: HloModuleProto,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _proto: proto.clone() }
    }
}

/// A compiled executable. Unconstructible in the stub.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("executable execution")
    }

    pub fn execute_b<B: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        _args: &[B],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("executable execution")
    }
}

/// The PJRT client. Host-side operations work; `compile` errors.
#[derive(Debug)]
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _priv: () })
    }

    pub fn platform_name(&self) -> String {
        "cpu-stub".to_string()
    }

    pub fn buffer_from_host_literal(
        &self,
        _device: Option<usize>,
        literal: &Literal,
    ) -> Result<PjRtBuffer> {
        Ok(PjRtBuffer { literal: literal.clone() })
    }

    pub fn compile(&self, _comp: &XlaComputation)
        -> Result<PjRtLoadedExecutable> {
        unavailable("HLO compilation")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0])
            .reshape(&[2, 2])
            .unwrap();
        assert_eq!(l.array_shape().unwrap().dims(), &[2, 2]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(l.size_bytes(), 16);
    }

    #[test]
    fn reshape_rejects_bad_count() {
        assert!(Literal::vec1(&[1.0; 5]).reshape(&[2, 3]).is_err());
    }

    #[test]
    fn client_boots_but_compile_errors() {
        let c = PjRtClient::cpu().unwrap();
        assert_eq!(c.platform_name(), "cpu-stub");
        let proto = HloModuleProto { _text: String::new() };
        let comp = XlaComputation::from_proto(&proto);
        assert!(c.compile(&comp).is_err());
    }
}
