//! Bench harness for paper fig10 (criterion is unavailable offline —
//! this is a plain main() reporting the paper's median-per-epoch
//! protocol via the experiments::fig10 driver).
//! Run: cargo bench --bench fig10_efficiency

fn main() {
    let args = fastvpinns::util::cli::Args::parse(
        std::env::args().skip(1).filter(|a| a != "--bench"),
    )
    .expect("args");
    if let Err(e) = fastvpinns::experiments::run("fig10", &args) {
        eprintln!("bench fig10 failed: {e:#}");
        std::process::exit(1);
    }
}
