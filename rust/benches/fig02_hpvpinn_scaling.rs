//! Bench harness for paper fig02 (criterion is unavailable offline —
//! this is a plain main() reporting the paper's median-per-epoch
//! protocol via the experiments::fig02 driver).
//! Run: cargo bench --bench fig02_hpvpinn_scaling

fn main() {
    let args = fastvpinns::util::cli::Args::parse(
        std::env::args().skip(1).filter(|a| a != "--bench"),
    )
    .expect("args");
    if let Err(e) = fastvpinns::experiments::run("fig02", &args) {
        eprintln!("bench fig02 failed: {e:#}");
        std::process::exit(1);
    }
}
