//! Bench harness for paper fig16 (criterion is unavailable offline —
//! this is a plain main() reporting the paper's median-per-epoch
//! protocol via the experiments::fig16 driver).
//! Run: cargo bench --bench fig16_hyperparam

fn main() {
    let args = fastvpinns::util::cli::Args::parse(
        std::env::args().skip(1).filter(|a| a != "--bench"),
    )
    .expect("args");
    if let Err(e) = fastvpinns::experiments::run("fig16", &args) {
        eprintln!("bench fig16 failed: {e:#}");
        std::process::exit(1);
    }
}
