//! Bench harness for paper table1 (criterion is unavailable offline —
//! this is a plain main() reporting the paper's median-per-epoch
//! protocol via the experiments::table1 driver).
//! Run: cargo bench --bench table1_fem_vs_predict

fn main() {
    let args = fastvpinns::util::cli::Args::parse(
        std::env::args().skip(1).filter(|a| a != "--bench"),
    )
    .expect("args");
    if let Err(e) = fastvpinns::experiments::run("table1", &args) {
        eprintln!("bench table1 failed: {e:#}");
        std::process::exit(1);
    }
}
