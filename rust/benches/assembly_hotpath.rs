//! Bench: the Rust-side hot paths outside the train step —
//! premultiplier tensor assembly (one-off per run, but dominates startup
//! for 14k-element meshes) and the f32 runtime-boundary conversion.
//! Covers the historical element counts plus a large ne=4096 grid to
//! exercise the even-chunk parallel split.
//! Run: cargo bench --bench assembly_hotpath

use std::time::Instant;

use fastvpinns::fem::assembly;
use fastvpinns::fem::quadrature::QuadKind;
use fastvpinns::mesh::generators;
use fastvpinns::util::stats;

fn time_median<F: FnMut()>(reps: usize, mut f: F) -> f64 {
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    stats::median(&samples)
}

fn main() {
    println!("== assembly (nt=4, nq=5 per direction) ==");
    for (label, mesh) in [
        ("square 20x20 (400 cells)",
         generators::unit_square(20)),
        ("skewed 20x20 (400 cells)",
         generators::skewed_square(20, 0.2)),
        ("disk 1024", generators::disk_1024()),
        ("gear 1760 (CI)", generators::gear_ci()),
        ("square 64x64 (4096 cells)",
         generators::unit_square(64)),
        ("gear 14080 (paper)", generators::gear_paper()),
    ] {
        let reps = if mesh.n_cells() > 5000 { 3 } else { 10 };
        let ms = time_median(reps, || {
            let d = assembly::assemble(&mesh, 4, 5,
                                       QuadKind::GaussLegendre);
            std::hint::black_box(d.gx.len());
        });
        let cells = mesh.n_cells();
        println!("  {label:<28} {ms:>9.2} ms  ({:.1} us/cell)",
                 ms * 1e3 / cells as f64);
    }

    println!("== force matrix (gear CI, nt=4, nq=5) ==");
    let mesh = generators::gear_ci();
    let d = assembly::assemble(&mesh, 4, 5, QuadKind::GaussLegendre);
    let ms = time_median(10, || {
        let f = d.force_matrix(|x, _| 50.0 * x.sin() + x.cos());
        std::hint::black_box(f.len());
    });
    println!("  force_matrix                  {ms:>9.2} ms");

    println!("== f32 runtime-boundary conversion (gear CI gx tensor) ==");
    let ms = time_median(10, || {
        let gx = d.gx_f32();
        std::hint::black_box(gx.len());
    });
    let mb = (d.gx.len() * 4) as f64 / 1e6;
    println!("  {:.1} MB tensor -> f32         {ms:>9.2} ms ({:.0} MB/s)",
             mb, mb / (ms / 1e3));
}
