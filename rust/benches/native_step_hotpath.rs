//! Bench: the native backend's train step (batched GEMM forward +
//! blocked residual contraction + batched backprop + Adam) across
//! element counts — the pure-Rust analogue of the paper's
//! median-time-per-epoch protocol, with no artifacts. The ne=4096 case
//! is the tracked acceptance point for the tensorized hot path.
//! Run: cargo bench --bench native_step_hotpath
//! (`repro bench` shares the per-case protocol via
//! `experiments::common::native_step_case` and writes the JSON record;
//! grid lists and iteration counts differ by harness.)

use fastvpinns::experiments::common::{
    native_forward_step_case, native_inverse_space_step_case,
    native_step_case, StepBenchCase,
};

fn print_case(case: &StepBenchCase) {
    let s = &case.summary;
    println!(
        "  {:<17} ne={:<5} ({:>6} quad pts)  median {:>8.3} ms/step  \
         p90 {:>8.3} ms",
        case.pde, case.ne, case.n_quad, s.median, s.p90
    );
}

fn main() {
    println!(
        "kernel: {} (cpu avx2={}, fma={})",
        fastvpinns::linalg::simd::kernel_name(),
        fastvpinns::linalg::simd::cpu_avx2(),
        fastvpinns::linalg::simd::cpu_fma(),
    );
    println!("== native train step, 30x3 net, nt=5x5, nq=5x5/elem ==");
    for k in [2usize, 4, 8, 16, 32, 64] {
        let ne = k * k;
        // fewer timed iters on the big grids keeps the sweep short
        let iters = if ne >= 1024 { 10 } else { 20 };
        print_case(&native_step_case(k, 5, 5, iters, 3)
            .expect("timed steps"));
    }
    println!("== generalized-form PDEs (reaction / hoisted b tables) ==");
    for pde in ["helmholtz", "cd_var", "poisson_tab"] {
        for k in [4usize, 16, 64] {
            print_case(&native_forward_step_case(pde, k, 5, 5, 20, 3)
                .expect("timed steps"));
        }
    }
    println!("== two-head inverse-space step (eps head in contraction) ==");
    for k in [4usize, 16, 64] {
        print_case(&native_inverse_space_step_case(k, 5, 5, 20, 3)
            .expect("timed steps"));
    }
}
