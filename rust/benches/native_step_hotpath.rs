//! Bench: the native backend's train step (forward + contraction +
//! backprop + Adam) across element counts — the pure-Rust analogue of
//! the paper's median-time-per-epoch protocol, with no artifacts.
//! Run: cargo bench --bench native_step_hotpath

use fastvpinns::coordinator::trainer::DataSource;
use fastvpinns::experiments::common::median_backend_step_ms;
use fastvpinns::fem::assembly;
use fastvpinns::fem::quadrature::QuadKind;
use fastvpinns::mesh::generators;
use fastvpinns::problems::PoissonSin;
use fastvpinns::runtime::backend::native::{NativeBackend, NativeConfig};
use fastvpinns::runtime::backend::BackendOpts;

fn main() {
    let problem = PoissonSin::new(2.0 * std::f64::consts::PI);
    println!("== native train step, 30x3 net, nt=5x5, nq=5x5/elem ==");
    for k in [2usize, 4, 8, 16, 20, 32] {
        let ne = k * k;
        let mesh = generators::unit_square(k);
        let dom = assembly::assemble(&mesh, 5, 5, QuadKind::GaussLegendre);
        let src = DataSource {
            mesh: &mesh,
            domain: Some(&dom),
            problem: &problem,
            sensor_values: None,
        };
        let cfg = NativeConfig::poisson_std();
        let mut b = NativeBackend::new(&cfg, &src, &BackendOpts::default())
            .expect("native backend");
        let ms = median_backend_step_ms(&mut b, 20, 3)
            .expect("timed steps");
        println!(
            "  ne={ne:<5} ({:>6} quad pts)  median {ms:>8.3} ms/step",
            ne * dom.nq
        );
    }
}
